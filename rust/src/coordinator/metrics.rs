//! Server-side metrics: throughput, latency percentiles, NFE, queueing,
//! micro-batching health (verify-batch and draft-wave occupancy, KV-
//! arena high-water mark, in-flight jobs), and fleet aggregation across
//! shards.
//!
//! Each shard worker accumulates its own [`ServerMetrics`]; after the
//! run, [`ServerMetrics::merge_fleet`] folds the per-shard metrics into
//! one fleet-wide view — cross-shard latency percentiles are merged at
//! the reservoir level ([`crate::util::stats::Reservoir::merge`]), and
//! the fleet summary reports per-shard verify occupancy plus a shard
//! imbalance gauge.
//!
//! Latency and queue-delay percentiles come from fixed-size reservoir
//! samples, so the metrics layer's memory is bounded no matter how many
//! requests the fleet serves.

use crate::coordinator::qos::{QosClass, ShedReason};
use crate::obs::span::{SpanKind, StageDist};
use crate::util::stats::{OnlineStats, Reservoir};
use std::collections::BTreeMap;
use std::time::Instant;

/// Retained latency / queue-delay observations per reservoir.
const RESERVOIR_CAP: usize = 4096;

/// Per-QoS-class accounting (populated only on QoS-enabled runs; the
/// legacy summary shape is untouched otherwise). The conservation law
/// `offered == served + shed` holds per class at the end of a run —
/// every offered request is either served (possibly degraded) or
/// rejected with a typed [`ShedReason`], never silently dropped.
#[derive(Debug, Clone)]
pub struct QosClassMetrics {
    /// Requests offered (arrived at a shard) in this class.
    pub offered: u64,
    /// Requests served to completion.
    pub served: u64,
    /// Requests shed by admission control, per typed reason.
    pub shed: BTreeMap<&'static str, u64>,
    /// Served requests that met their deadline (served requests without
    /// a deadline always count as hits).
    pub deadline_hits: u64,
    /// Served requests that missed their deadline.
    pub deadline_misses: u64,
    /// Requests served with degraded (drafter-heavy) parameters.
    pub degraded: u64,
    /// End-to-end latency reservoir over served requests.
    latencies: Reservoir,
}

impl Default for QosClassMetrics {
    fn default() -> Self {
        Self {
            offered: 0,
            served: 0,
            shed: BTreeMap::new(),
            deadline_hits: 0,
            deadline_misses: 0,
            degraded: 0,
            latencies: Reservoir::new(RESERVOIR_CAP),
        }
    }
}

impl QosClassMetrics {
    /// Total sheds across reasons.
    pub fn shed_total(&self) -> u64 {
        self.shed.values().sum()
    }

    /// Deadline-hit rate over *offered* requests (sheds and late
    /// completions both count against it; 0 when nothing was offered).
    pub fn hit_rate(&self) -> f64 {
        if self.offered == 0 {
            0.0
        } else {
            self.deadline_hits as f64 / self.offered as f64
        }
    }

    /// Latency percentile over served requests.
    pub fn latency_percentile(&self, q: f64) -> f64 {
        self.latencies.percentile(q)
    }

    fn merge(&mut self, other: &QosClassMetrics) {
        self.offered += other.offered;
        self.served += other.served;
        for (&reason, n) in &other.shed {
            *self.shed.entry(reason).or_insert(0) += n;
        }
        self.deadline_hits += other.deadline_hits;
        self.deadline_misses += other.deadline_misses;
        self.degraded += other.degraded;
        self.latencies.merge(&other.latencies);
    }
}

/// Metrics accumulated by one shard worker (or merged fleet-wide).
#[derive(Debug, Clone)]
pub struct ServerMetrics {
    started: Instant,
    /// When serving ended (set by `stop_clock` at shard-loop exit).
    /// `None` while still serving — `throughput` then measures to now.
    stopped: Option<Instant>,
    /// Shard that produced these metrics (`None` for a fleet merge).
    pub shard: Option<usize>,
    /// Completed segment requests.
    pub requests: u64,
    /// Queue-delay stats (seconds).
    pub queue_delay: OnlineStats,
    /// Compute-time stats (seconds).
    pub compute: OnlineStats,
    /// End-to-end latency reservoir (for percentiles).
    latencies: Reservoir,
    /// Queue-delay reservoir (for percentiles).
    queue_delays: Reservoir,
    /// Total NFE served.
    pub total_nfe: f64,
    /// Total drafts / accepted across requests.
    pub drafts: u64,
    /// Accepted drafts.
    pub accepted: u64,
    /// Fused verify calls issued by the engine.
    pub verify_batches: u64,
    /// Requests fused per verify call (batch occupancy; >1 means
    /// cross-request fusion is engaging).
    pub verify_occupancy: OnlineStats,
    /// Fused drafter waves issued by the engine
    /// (`drafter_rollout_many` calls).
    pub draft_waves: u64,
    /// Requests fused per drafter wave (draft-wave occupancy; >1 means
    /// continuous drafter batching is engaging).
    pub draft_wave_occupancy: OnlineStats,
    /// Peak KV-block demand of the drafter wave arena (0 when the
    /// backend has no arena; max across shards on a fleet merge).
    pub arena_blocks_peak: usize,
    /// In-flight job gauge, sampled once per engine iteration.
    pub inflight: OnlineStats,
    /// Peak concurrent in-flight jobs.
    pub peak_inflight: usize,
    /// Requests served per task name (heterogeneous-workload breakdown).
    pub task_requests: BTreeMap<&'static str, u64>,
    /// Requests served per method name.
    pub method_requests: BTreeMap<&'static str, u64>,
    /// Requests served per drafter identity
    /// ([`crate::coordinator::workload::DrafterKind`] names) — shows
    /// which drafter backend a run was served with when comparing
    /// `--drafter` swaps.
    pub drafter_requests: BTreeMap<&'static str, u64>,
    /// Scheduler policy versions observed on admitted adaptive requests
    /// (distribution across the run; online adaptation makes the mean
    /// climb as the learner publishes epochs, frozen serving pins it
    /// at 0).
    pub policy_epochs: OnlineStats,
    /// Newest policy epoch that served a request.
    pub policy_epoch_max: u64,
    /// Per-shard (shard id, requests, mean verify occupancy), populated
    /// by [`ServerMetrics::merge_fleet`]; empty on a single shard's own
    /// metrics.
    pub shard_breakdown: Vec<(usize, u64, f64)>,
    /// Per-QoS-class deadline/shed/degradation accounting, keyed by
    /// class name (`summary` renders it in priority order). Empty (and
    /// absent from `summary`) unless the run served with QoS enabled.
    pub qos_classes: BTreeMap<&'static str, QosClassMetrics>,
    /// Per-stage wall-time attribution (seconds), keyed by
    /// [`SpanKind::name`], fed by the span recorders when tracing is on.
    /// Empty (and absent from `summary`) on untraced runs, so the
    /// legacy summary shape is untouched.
    pub stage_times: BTreeMap<&'static str, StageDist>,
    /// HTTP responses by status code, fed by the network frontend
    /// (`crate::net`). Empty (and absent from `summary`) on in-process
    /// runs, so the legacy summary shape is untouched.
    pub http_status: BTreeMap<u16, u64>,
    /// Shard workers spawned by the autoscaler (0 — and absent from
    /// `summary` — on fixed-fleet runs).
    pub scale_ups: u64,
    /// Shards drained and retired by the autoscaler.
    pub scale_downs: u64,
    /// Deterministic session migrations performed by the dispatcher.
    pub migrations: u64,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    /// Fresh metrics; the throughput clock starts now.
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            stopped: None,
            shard: None,
            requests: 0,
            queue_delay: OnlineStats::new(),
            compute: OnlineStats::new(),
            latencies: Reservoir::new(RESERVOIR_CAP),
            queue_delays: Reservoir::new(RESERVOIR_CAP),
            total_nfe: 0.0,
            drafts: 0,
            accepted: 0,
            verify_batches: 0,
            verify_occupancy: OnlineStats::new(),
            draft_waves: 0,
            draft_wave_occupancy: OnlineStats::new(),
            arena_blocks_peak: 0,
            inflight: OnlineStats::new(),
            peak_inflight: 0,
            task_requests: BTreeMap::new(),
            method_requests: BTreeMap::new(),
            drafter_requests: BTreeMap::new(),
            policy_epochs: OnlineStats::new(),
            policy_epoch_max: 0,
            shard_breakdown: Vec::new(),
            qos_classes: BTreeMap::new(),
            stage_times: BTreeMap::new(),
            http_status: BTreeMap::new(),
            scale_ups: 0,
            scale_downs: 0,
            migrations: 0,
        }
    }

    /// Fresh metrics labelled with the owning shard.
    pub fn for_shard(shard: usize) -> Self {
        Self { shard: Some(shard), ..Self::new() }
    }

    /// Restart the throughput clock. The shard worker calls this when
    /// its first request arrives, so reported throughput measures
    /// serving time only — neither the (potentially long) replica
    /// compile window nor the fleet readiness barrier.
    pub fn restart_clock(&mut self) {
        self.started = Instant::now();
    }

    /// Freeze the throughput clock: the shard worker calls this when
    /// its engine loop exits, so a fast shard's seg/s is measured over
    /// its own serving window — not until whenever `summary` happens to
    /// be printed (possibly long after, while slower shards drain).
    pub fn stop_clock(&mut self) {
        self.stopped = Some(Instant::now());
    }

    /// Record one completed request.
    pub fn record(
        &mut self,
        queue_delay_secs: f64,
        compute_secs: f64,
        nfe: f64,
        drafts: usize,
        accepted: usize,
    ) {
        self.requests += 1;
        self.queue_delay.push(queue_delay_secs);
        self.compute.push(compute_secs);
        self.latencies.push(queue_delay_secs + compute_secs);
        self.queue_delays.push(queue_delay_secs);
        self.total_nfe += nfe;
        self.drafts += drafts as u64;
        self.accepted += accepted as u64;
    }

    /// Attribute one completed request to its task, method, and drafter
    /// identity (the heterogeneous-workload breakdown reported by
    /// `summary`).
    pub fn record_spec(
        &mut self,
        task: &'static str,
        method: &'static str,
        drafter: &'static str,
    ) {
        *self.task_requests.entry(task).or_insert(0) += 1;
        *self.method_requests.entry(method).or_insert(0) += 1;
        *self.drafter_requests.entry(drafter).or_insert(0) += 1;
    }

    /// Record the policy epoch an adaptive request was decided under.
    pub fn record_policy_epoch(&mut self, epoch: u64) {
        self.policy_epochs.push(epoch as f64);
        self.policy_epoch_max = self.policy_epoch_max.max(epoch);
    }

    /// Record one offered request in its QoS class (QoS-enabled runs
    /// only; call at shard ingest, before any admission decision).
    pub fn record_offered(&mut self, class: QosClass) {
        self.qos_classes.entry(class.name()).or_default().offered += 1;
    }

    /// Record one shed request (typed admission-control rejection).
    pub fn record_shed(&mut self, class: QosClass, reason: ShedReason) {
        let slot = self.qos_classes.entry(class.name()).or_default();
        *slot.shed.entry(reason.name()).or_insert(0) += 1;
    }

    /// Record one request admitted with degraded (drafter-heavy)
    /// parameters.
    pub fn record_degraded(&mut self, class: QosClass) {
        self.qos_classes.entry(class.name()).or_default().degraded += 1;
    }

    /// Record one served request's QoS outcome: end-to-end latency and
    /// whether it met its deadline (`None` = no deadline = counts as a
    /// hit — useful work is useful work).
    pub fn record_qos_served(
        &mut self,
        class: QosClass,
        latency_secs: f64,
        deadline_ms: Option<u64>,
    ) {
        let slot = self.qos_classes.entry(class.name()).or_default();
        slot.served += 1;
        slot.latencies.push(latency_secs);
        let hit = match deadline_ms {
            Some(ms) => latency_secs <= ms as f64 / 1000.0,
            None => true,
        };
        if hit {
            slot.deadline_hits += 1;
        } else {
            slot.deadline_misses += 1;
        }
    }

    /// Total sheds across classes (0 on non-QoS runs).
    pub fn shed_total(&self) -> u64 {
        self.qos_classes.values().map(|c| c.shed_total()).sum()
    }

    /// Total degraded admissions across classes.
    pub fn degraded_total(&self) -> u64 {
        self.qos_classes.values().map(|c| c.degraded).sum()
    }

    /// In-deadline goodput over the serving window: served requests
    /// that met their deadline (or had none) per second. 0 on non-QoS
    /// runs.
    pub fn in_deadline_goodput(&self) -> f64 {
        let hits: u64 = self.qos_classes.values().map(|c| c.deadline_hits).sum();
        let end = self.stopped.unwrap_or_else(Instant::now);
        let secs = end.saturating_duration_since(self.started).as_secs_f64();
        if secs > 0.0 {
            hits as f64 / secs
        } else {
            0.0
        }
    }

    /// The accounting for one class, if the run offered any requests in
    /// it.
    pub fn qos_class(&self, class: QosClass) -> Option<&QosClassMetrics> {
        self.qos_classes.get(class.name())
    }

    /// Record one fused verify call covering `fused` requests.
    pub fn record_verify_batch(&mut self, fused: usize) {
        self.verify_batches += 1;
        self.verify_occupancy.push(fused as f64);
    }

    /// Record one fused drafter wave covering `fused` requests.
    pub fn record_draft_wave(&mut self, fused: usize) {
        self.draft_waves += 1;
        self.draft_wave_occupancy.push(fused as f64);
    }

    /// Record the drafter arena's peak KV-block demand (monotone max —
    /// polled at shard shutdown, merged as max fleet-wide).
    pub fn record_arena_high_water(&mut self, blocks: usize) {
        self.arena_blocks_peak = self.arena_blocks_peak.max(blocks);
    }

    /// Mean requests fused per drafter wave (0 when no waves ran).
    pub fn mean_draft_wave_occupancy(&self) -> f64 {
        self.draft_wave_occupancy.mean()
    }

    /// Sample the in-flight job gauge (once per engine iteration).
    pub fn record_inflight(&mut self, jobs: usize) {
        self.inflight.push(jobs as f64);
        self.peak_inflight = self.peak_inflight.max(jobs);
    }

    /// Fold one stage's observed wall-time distribution into the
    /// attribution table (span-recorder handoff at shard exit, and
    /// session/learner sink folding at fleet merge).
    pub fn record_stage(&mut self, stage: &'static str, dist: &StageDist) {
        self.stage_times.entry(stage).or_default().merge(dist);
    }

    /// Count one HTTP response by status code (network frontend only).
    pub fn record_http_status(&mut self, status: u16) {
        *self.http_status.entry(status).or_insert(0) += 1;
    }

    /// Stage percentile in seconds (q in [0,1]; 0 for unknown stages).
    pub fn stage_percentile(&self, stage: &str, q: f64) -> f64 {
        self.stage_times.get(stage).map_or(0.0, |d| d.reservoir.percentile(q))
    }

    /// Fold per-shard metrics into one fleet-wide view: counters sum,
    /// online stats merge (parallel Welford), latency/queue percentiles
    /// merge at the reservoir level, and the per-shard breakdown
    /// (requests + verify occupancy per shard) is retained for the
    /// summary line and the imbalance gauge.
    pub fn merge_fleet(shards: &[ServerMetrics]) -> ServerMetrics {
        let mut fleet = ServerMetrics::new();
        if let Some(earliest) = shards.iter().map(|m| m.started).min() {
            fleet.started = earliest;
        }
        // The fleet's serving window closes when the LAST shard stops
        // (left open if any shard is still serving).
        if shards.iter().all(|m| m.stopped.is_some()) {
            fleet.stopped = shards.iter().filter_map(|m| m.stopped).max();
        }
        for m in shards {
            fleet.requests += m.requests;
            fleet.queue_delay.merge(&m.queue_delay);
            fleet.compute.merge(&m.compute);
            fleet.latencies.merge(&m.latencies);
            fleet.queue_delays.merge(&m.queue_delays);
            fleet.total_nfe += m.total_nfe;
            fleet.drafts += m.drafts;
            fleet.accepted += m.accepted;
            fleet.verify_batches += m.verify_batches;
            fleet.verify_occupancy.merge(&m.verify_occupancy);
            fleet.draft_waves += m.draft_waves;
            fleet.draft_wave_occupancy.merge(&m.draft_wave_occupancy);
            fleet.arena_blocks_peak = fleet.arena_blocks_peak.max(m.arena_blocks_peak);
            fleet.inflight.merge(&m.inflight);
            fleet.peak_inflight = fleet.peak_inflight.max(m.peak_inflight);
            for (task, n) in &m.task_requests {
                *fleet.task_requests.entry(task).or_insert(0) += n;
            }
            for (method, n) in &m.method_requests {
                *fleet.method_requests.entry(method).or_insert(0) += n;
            }
            for (drafter, n) in &m.drafter_requests {
                *fleet.drafter_requests.entry(drafter).or_insert(0) += n;
            }
            fleet.policy_epochs.merge(&m.policy_epochs);
            fleet.policy_epoch_max = fleet.policy_epoch_max.max(m.policy_epoch_max);
            for (&class, qm) in &m.qos_classes {
                fleet.qos_classes.entry(class).or_default().merge(qm);
            }
            for (&stage, dist) in &m.stage_times {
                fleet.stage_times.entry(stage).or_default().merge(dist);
            }
            for (&status, n) in &m.http_status {
                *fleet.http_status.entry(status).or_insert(0) += n;
            }
            fleet.scale_ups += m.scale_ups;
            fleet.scale_downs += m.scale_downs;
            fleet.migrations += m.migrations;
            fleet.shard_breakdown.push((
                m.shard.unwrap_or(fleet.shard_breakdown.len()),
                m.requests,
                m.mean_verify_occupancy(),
            ));
        }
        fleet
    }

    /// Shard imbalance gauge: max over mean of per-shard request counts
    /// (1.0 = perfectly balanced; meaningful only on a fleet merge).
    pub fn shard_imbalance(&self) -> f64 {
        if self.shard_breakdown.is_empty() {
            return 1.0;
        }
        let max = self.shard_breakdown.iter().map(|&(_, r, _)| r).max().unwrap_or(0) as f64;
        let mean = self.shard_breakdown.iter().map(|&(_, r, _)| r).sum::<u64>() as f64
            / self.shard_breakdown.len() as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Mean requests fused per verify call (0 when no verifies ran).
    pub fn mean_verify_occupancy(&self) -> f64 {
        self.verify_occupancy.mean()
    }

    /// Retained latency observations (bounded by the reservoir capacity;
    /// exposed for the memory-regression test).
    pub fn latency_samples(&self) -> usize {
        self.latencies.len()
    }

    /// Segments per second over the serving window (start of serving
    /// until `stop_clock`, or until now while still serving).
    pub fn throughput(&self) -> f64 {
        let end = self.stopped.unwrap_or_else(Instant::now);
        let secs = end.saturating_duration_since(self.started).as_secs_f64();
        if secs > 0.0 {
            self.requests as f64 / secs
        } else {
            0.0
        }
    }

    /// End-to-end latency percentile (q in [0,1]).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        self.latencies.percentile(q)
    }

    /// Queue-delay percentile (q in [0,1]).
    pub fn queue_delay_percentile(&self, q: f64) -> f64 {
        self.queue_delays.percentile(q)
    }

    /// Draft acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafts == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafts as f64
        }
    }

    /// One-line human summary. A fleet merge appends the per-shard
    /// occupancy breakdown, the imbalance gauge, and the distinct
    /// task/method counts of the heterogeneous workload.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "requests={} throughput={:.2} seg/s nfe/seg={:.1} accept={:.1}% \
             latency p50={:.4}s p95={:.4}s p99={:.4}s queue p95={:.4}s \
             verify-occ={:.2} inflight mean={:.1} peak={}",
            self.requests,
            self.throughput(),
            self.total_nfe / self.requests.max(1) as f64,
            self.acceptance_rate() * 100.0,
            self.latency_percentile(0.50),
            self.latency_percentile(0.95),
            self.latency_percentile(0.99),
            self.queue_delay_percentile(0.95),
            self.mean_verify_occupancy(),
            self.inflight.mean(),
            self.peak_inflight,
        );
        // Drafter-wave gauges: appended only when continuous drafter
        // batching ran, so serial runs keep the legacy summary shape.
        if self.draft_waves > 0 {
            s.push_str(&format!(
                " draft-waves={} draft-occ={:.2}",
                self.draft_waves,
                self.mean_draft_wave_occupancy()
            ));
            if self.arena_blocks_peak > 0 {
                s.push_str(&format!(" kv-blocks-peak={}", self.arena_blocks_peak));
            }
        }
        if let Some(shard) = self.shard {
            s = format!("shard={shard} {s}");
        }
        if !self.task_requests.is_empty() {
            s.push_str(&format!(
                " tasks={} methods={}",
                self.task_requests.len(),
                self.method_requests.len()
            ));
            // Drafter identity: shown whenever a non-base drafter served
            // requests (base-only runs keep the legacy summary shape).
            if self.drafter_requests.keys().any(|d| *d != "base") {
                let parts: Vec<String> = self
                    .drafter_requests
                    .iter()
                    .map(|(d, n)| format!("{d}:{n}"))
                    .collect();
                s.push_str(&format!(" drafters=[{}]", parts.join(" ")));
            }
        }
        if self.policy_epochs.count() > 0 {
            s.push_str(&format!(
                " policy-epoch mean={:.1} max={}",
                self.policy_epochs.mean(),
                self.policy_epoch_max
            ));
        }
        if !self.shard_breakdown.is_empty() {
            let occ: Vec<String> = self
                .shard_breakdown
                .iter()
                .map(|&(id, _, occ)| format!("{id}:{occ:.2}"))
                .collect();
            s.push_str(&format!(
                " shards={} imbalance={:.2} shard-occ=[{}]",
                self.shard_breakdown.len(),
                self.shard_imbalance(),
                occ.join(" ")
            ));
        }
        // QoS accounting (QoS-enabled runs only), classes in priority
        // order: offered / shed / deadline-hit rate / degraded / p95.
        if !self.qos_classes.is_empty() {
            let parts: Vec<String> = QosClass::ALL
                .iter()
                .filter_map(|&c| self.qos_classes.get(c.name()).map(|m| (c, m)))
                .map(|(c, m)| {
                    format!(
                        "{}: off={} srv={} shed={} hit={:.1}% degr={} p95={:.4}s",
                        c.name(),
                        m.offered,
                        m.served,
                        m.shed_total(),
                        m.hit_rate() * 100.0,
                        m.degraded,
                        m.latency_percentile(0.95),
                    )
                })
                .collect();
            s.push_str(&format!(
                " qos=[{}] in-deadline-goodput={:.2}/s",
                parts.join(" | "),
                self.in_deadline_goodput()
            ));
        }
        // Per-stage wall-time attribution (traced runs only), stages in
        // pipeline order; times in milliseconds.
        if !self.stage_times.is_empty() {
            let parts: Vec<String> = SpanKind::ALL
                .iter()
                .filter_map(|&k| self.stage_times.get(k.name()).map(|d| (k, d)))
                .map(|(k, d)| {
                    format!(
                        "{} n={} p50={:.3}ms p95={:.3}ms p99={:.3}ms",
                        k.name(),
                        d.stats.count(),
                        d.reservoir.percentile(0.50) * 1e3,
                        d.reservoir.percentile(0.95) * 1e3,
                        d.reservoir.percentile(0.99) * 1e3,
                    )
                })
                .collect();
            s.push_str(&format!(" stages=[{}]", parts.join(" | ")));
        }
        // HTTP status breakdown (network-frontend runs only), ascending
        // status order (BTreeMap iteration).
        if !self.http_status.is_empty() {
            let parts: Vec<String> =
                self.http_status.iter().map(|(code, n)| format!("{code}:{n}")).collect();
            s.push_str(&format!(" http=[{}]", parts.join(" ")));
        }
        // Elastic-fleet accounting (autoscaled runs only): fixed fleets
        // keep the legacy summary shape.
        if self.scale_ups > 0 || self.scale_downs > 0 || self.migrations > 0 {
            s.push_str(&format!(
                " elastic=[ups={} downs={} migrations={}]",
                self.scale_ups, self.scale_downs, self.migrations
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_percentiles() {
        let mut m = ServerMetrics::new();
        for i in 0..100 {
            m.record(0.001, 0.01 + i as f64 * 0.0001, 25.0, 10, 9);
        }
        assert_eq!(m.requests, 100);
        assert!((m.acceptance_rate() - 0.9).abs() < 1e-12);
        assert!(m.latency_percentile(0.5) > 0.01);
        assert!(m.latency_percentile(0.99) >= m.latency_percentile(0.5));
        assert!((m.total_nfe - 2500.0).abs() < 1e-9);
        assert!(m.throughput() > 0.0);
        assert!(m.summary().contains("requests=100"));
    }

    #[test]
    fn memory_stays_bounded_under_load() {
        // Regression: percentile buffers must not grow per request.
        let mut m = ServerMetrics::new();
        for i in 0..(RESERVOIR_CAP * 10) {
            m.record(0.001 * (i % 7) as f64, 0.01, 25.0, 8, 7);
        }
        assert_eq!(m.requests as usize, RESERVOIR_CAP * 10);
        assert!(m.latency_samples() <= RESERVOIR_CAP);
        // Percentiles still answer sensibly from the reservoir.
        let p50 = m.latency_percentile(0.5);
        assert!(p50 >= 0.01 && p50 <= 0.01 + 0.006 + 1e-9, "p50 {p50}");
    }

    #[test]
    fn batching_gauges_accumulate() {
        let mut m = ServerMetrics::new();
        m.record_verify_batch(4);
        m.record_verify_batch(2);
        m.record_inflight(4);
        m.record_inflight(6);
        m.record_inflight(1);
        assert_eq!(m.verify_batches, 2);
        assert!((m.mean_verify_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(m.peak_inflight, 6);
        assert!((m.inflight.mean() - 11.0 / 3.0).abs() < 1e-12);
        assert!(m.summary().contains("verify-occ"));
    }

    #[test]
    fn draft_wave_gauges_accumulate_and_merge() {
        let mut a = ServerMetrics::for_shard(0);
        let mut b = ServerMetrics::for_shard(1);
        a.record_draft_wave(4);
        a.record_draft_wave(2);
        a.record_arena_high_water(10);
        a.record_arena_high_water(7); // monotone max: stays 10
        b.record_draft_wave(1);
        b.record_arena_high_water(12);
        assert_eq!(a.draft_waves, 2);
        assert!((a.mean_draft_wave_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(a.arena_blocks_peak, 10);
        let s = a.summary();
        assert!(s.contains("draft-waves=2 draft-occ=3.00"), "{s}");
        assert!(s.contains("kv-blocks-peak=10"), "{s}");
        let fleet = ServerMetrics::merge_fleet(&[a, b]);
        assert_eq!(fleet.draft_waves, 3);
        assert!((fleet.mean_draft_wave_occupancy() - 7.0 / 3.0).abs() < 1e-12);
        assert_eq!(fleet.arena_blocks_peak, 12, "fleet peak is the max across shards");
        // Runs without drafter batching keep the legacy summary shape.
        let plain = ServerMetrics::new();
        assert!(!plain.summary().contains("draft-waves"), "{}", plain.summary());
        assert!(!plain.summary().contains("kv-blocks-peak"), "{}", plain.summary());
    }

    #[test]
    fn fleet_merge_sums_and_breaks_down_shards() {
        let mut a = ServerMetrics::for_shard(0);
        let mut b = ServerMetrics::for_shard(1);
        for _ in 0..30 {
            a.record(0.001, 0.01, 20.0, 8, 7);
            a.record_spec("lift", "ts_dp", "distilled");
        }
        for _ in 0..10 {
            b.record(0.002, 0.03, 100.0, 0, 0);
            b.record_spec("push_t", "vanilla", "base");
        }
        a.record_verify_batch(4);
        a.record_verify_batch(4);
        b.record_verify_batch(1);
        let fleet = ServerMetrics::merge_fleet(&[a, b]);
        assert_eq!(fleet.requests, 40);
        assert_eq!(fleet.verify_batches, 3);
        assert!((fleet.total_nfe - (30.0 * 20.0 + 10.0 * 100.0)).abs() < 1e-9);
        assert_eq!(fleet.task_requests["lift"], 30);
        assert_eq!(fleet.method_requests["vanilla"], 10);
        assert_eq!(fleet.shard_breakdown.len(), 2);
        assert_eq!(fleet.shard_breakdown[0], (0, 30, 4.0));
        assert_eq!(fleet.shard_breakdown[1].1, 10);
        // imbalance = max/mean = 30/20.
        assert!((fleet.shard_imbalance() - 1.5).abs() < 1e-12);
        assert_eq!(fleet.drafter_requests["distilled"], 30);
        assert_eq!(fleet.drafter_requests["base"], 10);
        let s = fleet.summary();
        assert!(s.contains("shard-occ=[0:4.00 1:1.00]"), "{s}");
        assert!(s.contains("imbalance=1.50"), "{s}");
        assert!(s.contains("tasks=2 methods=2"), "{s}");
        assert!(s.contains("drafters=[base:10 distilled:30]"), "{s}");
        // Percentiles answer from the merged reservoirs.
        assert!(fleet.latency_percentile(0.5) > 0.0);
        assert!(fleet.latency_percentile(0.99) >= fleet.latency_percentile(0.5));
    }

    #[test]
    fn base_only_runs_keep_the_legacy_summary_shape() {
        let mut m = ServerMetrics::new();
        m.record(0.001, 0.01, 20.0, 8, 7);
        m.record_spec("lift", "ts_dp", "base");
        let s = m.summary();
        assert!(s.contains("tasks=1 methods=1"), "{s}");
        assert!(!s.contains("drafters="), "base-only must not grow the line: {s}");
    }

    #[test]
    fn shard_label_appears_in_summary() {
        let m = ServerMetrics::for_shard(3);
        assert!(m.summary().starts_with("shard=3 "));
        assert_eq!(ServerMetrics::new().shard, None);
    }

    #[test]
    fn qos_counters_account_and_merge() {
        let mut a = ServerMetrics::for_shard(0);
        let mut b = ServerMetrics::for_shard(1);
        for _ in 0..10 {
            a.record_offered(QosClass::Realtime);
        }
        for _ in 0..7 {
            a.record_qos_served(QosClass::Realtime, 0.020, Some(40));
        }
        a.record_qos_served(QosClass::Realtime, 0.090, Some(40)); // miss
        a.record_shed(QosClass::Realtime, ShedReason::Expired);
        a.record_shed(QosClass::Realtime, ShedReason::DeadlineUnmeetable);
        a.record_degraded(QosClass::Realtime);
        b.record_offered(QosClass::Batch);
        b.record_qos_served(QosClass::Batch, 3.0, None); // no deadline = hit
        let fleet = ServerMetrics::merge_fleet(&[a, b]);
        let rt = fleet.qos_class(QosClass::Realtime).unwrap();
        assert_eq!(rt.offered, 10);
        assert_eq!(rt.served, 8);
        assert_eq!(rt.shed_total(), 2);
        assert_eq!(rt.shed["expired"], 1);
        assert_eq!(rt.shed["unmeetable"], 1);
        assert_eq!(rt.offered, rt.served + rt.shed_total(), "conservation law");
        assert_eq!(rt.deadline_hits, 7);
        assert_eq!(rt.deadline_misses, 1);
        assert_eq!(rt.degraded, 1);
        assert!((rt.hit_rate() - 0.7).abs() < 1e-12);
        let batch = fleet.qos_class(QosClass::Batch).unwrap();
        assert_eq!(batch.deadline_hits, 1, "deadline-free work counts as useful");
        assert_eq!(fleet.shed_total(), 2);
        assert_eq!(fleet.degraded_total(), 1);
        let s = fleet.summary();
        assert!(s.contains("qos=[rt: off=10 srv=8 shed=2 hit=70.0% degr=1"), "{s}");
        assert!(s.contains("| batch: off=1"), "{s}");
        assert!(s.contains("in-deadline-goodput="), "{s}");
        // Priority order in the summary: rt before batch.
        assert!(s.find("rt:").unwrap() < s.find("batch:").unwrap(), "{s}");
    }

    #[test]
    fn non_qos_runs_keep_the_legacy_summary_shape() {
        let mut m = ServerMetrics::new();
        m.record(0.001, 0.01, 20.0, 8, 7);
        assert!(!m.summary().contains("qos=["), "{}", m.summary());
        assert_eq!(m.shed_total(), 0);
        assert_eq!(m.in_deadline_goodput(), 0.0);
        assert!(m.qos_class(QosClass::Realtime).is_none());
    }

    #[test]
    fn policy_epoch_gauge_tracks_and_merges() {
        let mut a = ServerMetrics::for_shard(0);
        let mut b = ServerMetrics::for_shard(1);
        for e in [0u64, 0, 1, 2] {
            a.record_policy_epoch(e);
        }
        b.record_policy_epoch(5);
        let fleet = ServerMetrics::merge_fleet(&[a, b]);
        assert_eq!(fleet.policy_epoch_max, 5);
        assert_eq!(fleet.policy_epochs.count(), 5);
        assert!((fleet.policy_epochs.mean() - 8.0 / 5.0).abs() < 1e-12);
        let s = fleet.summary();
        assert!(s.contains("policy-epoch mean=1.6 max=5"), "{s}");
        // Non-adaptive runs keep the legacy summary shape.
        let plain = ServerMetrics::new();
        assert!(!plain.summary().contains("policy-epoch"), "{}", plain.summary());
    }

    #[test]
    fn stage_attribution_merges_and_renders_conditionally() {
        // Untraced runs keep the legacy summary shape.
        let plain = ServerMetrics::new();
        assert!(!plain.summary().contains("stages=["), "{}", plain.summary());
        // Shard-side attribution folds through the fleet merge.
        let mut verify_a = StageDist::new();
        for _ in 0..10 {
            verify_a.push(0.002);
        }
        let mut verify_b = StageDist::new();
        for _ in 0..30 {
            verify_b.push(0.004);
        }
        let mut queue = StageDist::new();
        queue.push(0.0005);
        let mut a = ServerMetrics::for_shard(0);
        a.record_stage(SpanKind::VerifyCall.name(), &verify_a);
        a.record_stage(SpanKind::QueueWait.name(), &queue);
        let mut b = ServerMetrics::for_shard(1);
        b.record_stage(SpanKind::VerifyCall.name(), &verify_b);
        let fleet = ServerMetrics::merge_fleet(&[a, b]);
        let d = fleet.stage_times.get("verify").expect("verify stage merged");
        assert_eq!(d.stats.count(), 40);
        assert!((fleet.stage_percentile("verify", 0.95) - 0.004).abs() < 1e-9);
        assert!((fleet.stage_percentile("queue_wait", 0.5) - 0.0005).abs() < 1e-12);
        assert_eq!(fleet.stage_percentile("no_such_stage", 0.5), 0.0);
        let s = fleet.summary();
        assert!(s.contains("stages=["), "{s}");
        // Pipeline order: queue_wait renders before verify.
        let qpos = s.find("queue_wait n=1").expect("queue_wait rendered");
        let vpos = s.find("verify n=40").expect("verify rendered");
        assert!(qpos < vpos, "{s}");
    }

    #[test]
    fn elastic_counters_merge_and_render_conditionally() {
        // Fixed-fleet runs keep the legacy summary shape.
        let plain = ServerMetrics::new();
        assert!(!plain.summary().contains("elastic=["), "{}", plain.summary());
        let mut a = ServerMetrics::for_shard(0);
        a.scale_ups = 2;
        a.migrations = 3;
        let mut b = ServerMetrics::for_shard(1);
        b.scale_downs = 1;
        b.migrations = 1;
        let fleet = ServerMetrics::merge_fleet(&[a, b]);
        assert_eq!(fleet.scale_ups, 2);
        assert_eq!(fleet.scale_downs, 1);
        assert_eq!(fleet.migrations, 4);
        let s = fleet.summary();
        assert!(s.contains("elastic=[ups=2 downs=1 migrations=4]"), "{s}");
    }

    #[test]
    fn http_status_counters_merge_and_render_conditionally() {
        // In-process runs keep the legacy summary shape.
        let plain = ServerMetrics::new();
        assert!(!plain.summary().contains("http=["), "{}", plain.summary());
        let mut a = ServerMetrics::for_shard(0);
        a.record_http_status(200);
        a.record_http_status(200);
        a.record_http_status(429);
        let mut b = ServerMetrics::for_shard(1);
        b.record_http_status(200);
        b.record_http_status(503);
        let fleet = ServerMetrics::merge_fleet(&[a, b]);
        assert_eq!(fleet.http_status.get(&200), Some(&3));
        assert_eq!(fleet.http_status.get(&429), Some(&1));
        assert_eq!(fleet.http_status.get(&503), Some(&1));
        let s = fleet.summary();
        // Ascending status order (BTreeMap iteration).
        assert!(s.contains("http=[200:3 429:1 503:1]"), "{s}");
    }
}
