//! Server-side metrics: throughput, latency percentiles, NFE, queueing,
//! and micro-batching health (verify-batch occupancy, in-flight jobs).
//!
//! Latency and queue-delay percentiles come from fixed-size reservoir
//! samples, so the metrics layer's memory is bounded no matter how many
//! requests the engine serves.

use crate::util::stats::{OnlineStats, Reservoir};
use std::time::Instant;

/// Retained latency / queue-delay observations per reservoir.
const RESERVOIR_CAP: usize = 4096;

/// Metrics accumulated by the engine thread.
#[derive(Debug)]
pub struct ServerMetrics {
    started: Instant,
    /// Completed segment requests.
    pub requests: u64,
    /// Queue-delay stats (seconds).
    pub queue_delay: OnlineStats,
    /// Compute-time stats (seconds).
    pub compute: OnlineStats,
    /// End-to-end latency reservoir (for percentiles).
    latencies: Reservoir,
    /// Queue-delay reservoir (for percentiles).
    queue_delays: Reservoir,
    /// Total NFE served.
    pub total_nfe: f64,
    /// Total drafts / accepted across requests.
    pub drafts: u64,
    /// Accepted drafts.
    pub accepted: u64,
    /// Fused verify calls issued by the engine.
    pub verify_batches: u64,
    /// Requests fused per verify call (batch occupancy; >1 means
    /// cross-request fusion is engaging).
    pub verify_occupancy: OnlineStats,
    /// In-flight job gauge, sampled once per engine iteration.
    pub inflight: OnlineStats,
    /// Peak concurrent in-flight jobs.
    pub peak_inflight: usize,
}

impl Default for ServerMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServerMetrics {
    /// Fresh metrics; the throughput clock starts now.
    pub fn new() -> Self {
        Self {
            started: Instant::now(),
            requests: 0,
            queue_delay: OnlineStats::new(),
            compute: OnlineStats::new(),
            latencies: Reservoir::new(RESERVOIR_CAP),
            queue_delays: Reservoir::new(RESERVOIR_CAP),
            total_nfe: 0.0,
            drafts: 0,
            accepted: 0,
            verify_batches: 0,
            verify_occupancy: OnlineStats::new(),
            inflight: OnlineStats::new(),
            peak_inflight: 0,
        }
    }

    /// Record one completed request.
    pub fn record(
        &mut self,
        queue_delay_secs: f64,
        compute_secs: f64,
        nfe: f64,
        drafts: usize,
        accepted: usize,
    ) {
        self.requests += 1;
        self.queue_delay.push(queue_delay_secs);
        self.compute.push(compute_secs);
        self.latencies.push(queue_delay_secs + compute_secs);
        self.queue_delays.push(queue_delay_secs);
        self.total_nfe += nfe;
        self.drafts += drafts as u64;
        self.accepted += accepted as u64;
    }

    /// Record one fused verify call covering `fused` requests.
    pub fn record_verify_batch(&mut self, fused: usize) {
        self.verify_batches += 1;
        self.verify_occupancy.push(fused as f64);
    }

    /// Sample the in-flight job gauge (once per engine iteration).
    pub fn record_inflight(&mut self, jobs: usize) {
        self.inflight.push(jobs as f64);
        self.peak_inflight = self.peak_inflight.max(jobs);
    }

    /// Mean requests fused per verify call (0 when no verifies ran).
    pub fn mean_verify_occupancy(&self) -> f64 {
        self.verify_occupancy.mean()
    }

    /// Retained latency observations (bounded by the reservoir capacity;
    /// exposed for the memory-regression test).
    pub fn latency_samples(&self) -> usize {
        self.latencies.len()
    }

    /// Segments per second since start.
    pub fn throughput(&self) -> f64 {
        let secs = self.started.elapsed().as_secs_f64();
        if secs > 0.0 {
            self.requests as f64 / secs
        } else {
            0.0
        }
    }

    /// End-to-end latency percentile (q in [0,1]).
    pub fn latency_percentile(&self, q: f64) -> f64 {
        self.latencies.percentile(q)
    }

    /// Queue-delay percentile (q in [0,1]).
    pub fn queue_delay_percentile(&self, q: f64) -> f64 {
        self.queue_delays.percentile(q)
    }

    /// Draft acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        if self.drafts == 0 {
            0.0
        } else {
            self.accepted as f64 / self.drafts as f64
        }
    }

    /// One-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests={} throughput={:.2} seg/s nfe/seg={:.1} accept={:.1}% \
             latency p50={:.4}s p95={:.4}s p99={:.4}s queue p95={:.4}s \
             verify-occ={:.2} inflight mean={:.1} peak={}",
            self.requests,
            self.throughput(),
            self.total_nfe / self.requests.max(1) as f64,
            self.acceptance_rate() * 100.0,
            self.latency_percentile(0.50),
            self.latency_percentile(0.95),
            self.latency_percentile(0.99),
            self.queue_delay_percentile(0.95),
            self.mean_verify_occupancy(),
            self.inflight.mean(),
            self.peak_inflight,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_percentiles() {
        let mut m = ServerMetrics::new();
        for i in 0..100 {
            m.record(0.001, 0.01 + i as f64 * 0.0001, 25.0, 10, 9);
        }
        assert_eq!(m.requests, 100);
        assert!((m.acceptance_rate() - 0.9).abs() < 1e-12);
        assert!(m.latency_percentile(0.5) > 0.01);
        assert!(m.latency_percentile(0.99) >= m.latency_percentile(0.5));
        assert!((m.total_nfe - 2500.0).abs() < 1e-9);
        assert!(m.throughput() > 0.0);
        assert!(m.summary().contains("requests=100"));
    }

    #[test]
    fn memory_stays_bounded_under_load() {
        // Regression: percentile buffers must not grow per request.
        let mut m = ServerMetrics::new();
        for i in 0..(RESERVOIR_CAP * 10) {
            m.record(0.001 * (i % 7) as f64, 0.01, 25.0, 8, 7);
        }
        assert_eq!(m.requests as usize, RESERVOIR_CAP * 10);
        assert!(m.latency_samples() <= RESERVOIR_CAP);
        // Percentiles still answer sensibly from the reservoir.
        let p50 = m.latency_percentile(0.5);
        assert!(p50 >= 0.01 && p50 <= 0.01 + 0.006 + 1e-9, "p50 {p50}");
    }

    #[test]
    fn batching_gauges_accumulate() {
        let mut m = ServerMetrics::new();
        m.record_verify_batch(4);
        m.record_verify_batch(2);
        m.record_inflight(4);
        m.record_inflight(6);
        m.record_inflight(1);
        assert_eq!(m.verify_batches, 2);
        assert!((m.mean_verify_occupancy() - 3.0).abs() < 1e-12);
        assert_eq!(m.peak_inflight, 6);
        assert!((m.inflight.mean() - 11.0 / 3.0).abs() < 1e-12);
        assert!(m.summary().contains("verify-occ"));
    }
}
