//! The `Denoiser` abstraction: what the speculative engine needs from a
//! diffusion policy.
//!
//! The production implementation is [`crate::runtime::ModelRuntime`]
//! (PJRT executables, behind the `pjrt` feature); tests and the PPO
//! scheduler's training loop can also run against
//! [`mock::MockDenoiser`], an analytic target/drafter pair with a
//! controllable disagreement — so every algorithmic property of the
//! engine is testable without artifacts.
//!
//! Denoisers are deliberately **not** required to be `Send` (PJRT
//! handles are raw C pointers). The sharded serving fleet therefore
//! never moves a denoiser across threads: each shard worker builds its
//! own replica on its own thread through a
//! [`crate::coordinator::server::ReplicaFactory`] and owns it for the
//! lifetime of the run.

pub mod mock;

use crate::config::{EMBED_DIM, VERIFY_BATCH};
use crate::runtime::executable::SEG;
use crate::runtime::{ModelRuntime, NfeCounter};
use anyhow::{ensure, Result};

/// Model evaluations used by the denoising engines.
///
/// All tensors are flat row-major `f32` slices; shapes are fixed by
/// `crate::config` (x: HORIZON×ACT_DIM, cond: EMBED_DIM).
pub trait Denoiser {
    /// Observation encoder: obs[OBS_DIM] → cond[EMBED_DIM].
    fn encode(&self, obs: &[f32]) -> Result<Vec<f32>>;
    /// Target ε-prediction at one latent/timestep. Costs 1 NFE.
    fn target_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>>;
    /// Batched target ε-prediction over VERIFY_BATCH candidates in one
    /// parallel forward pass. Costs 1 NFE.
    fn target_verify(&self, xs: &[f32], ts: &[f32], cond: &[f32]) -> Result<Vec<f32>>;
    /// Multi-request fused verification: `n` requests' verify batches in
    /// one call, each with its own conditioning vector.
    ///
    /// Layout: `xs` is n × VERIFY_BATCH × SEG, `ts` is n × VERIFY_BATCH,
    /// `conds` is n × EMBED_DIM; the output is n × VERIFY_BATCH × SEG in
    /// the same request order. Costs 1 NFE *per request* (each request's
    /// share is one parallel target forward — fusing across requests
    /// amortizes dispatch, not model evaluations), so per-request NFE
    /// accounting is independent of how many requests share a call.
    ///
    /// The default implementation loops over per-request
    /// [`Denoiser::target_verify`] calls and is bit-identical to serving
    /// the requests one at a time; backends with a multi-conditioning
    /// verify kernel can override it with a genuinely fused forward.
    /// [`mock::MockDenoiser`] overrides it with a fused evaluation;
    /// [`ModelRuntime`] uses this loop until a multi-conditioning verify
    /// artifact is exported (its compiled `target_verify` shares one cond
    /// across the batch).
    fn target_verify_many(&self, xs: &[f32], ts: &[f32], conds: &[f32]) -> Result<Vec<f32>> {
        ensure!(conds.len() % EMBED_DIM == 0, "conds len {}", conds.len());
        let n = conds.len() / EMBED_DIM;
        ensure!(xs.len() == n * VERIFY_BATCH * SEG, "xs len {}", xs.len());
        ensure!(ts.len() == n * VERIFY_BATCH, "ts len {}", ts.len());
        let mut out = Vec::with_capacity(xs.len());
        for r in 0..n {
            let eps = self.target_verify(
                &xs[r * VERIFY_BATCH * SEG..(r + 1) * VERIFY_BATCH * SEG],
                &ts[r * VERIFY_BATCH..(r + 1) * VERIFY_BATCH],
                &conds[r * EMBED_DIM..(r + 1) * EMBED_DIM],
            )?;
            out.extend_from_slice(&eps);
        }
        Ok(out)
    }
    /// Drafter ε-prediction. Costs 1/8 NFE.
    fn drafter_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>>;
    /// Fused K-step drafter rollout, if the backend supports `k`:
    /// returns (draft samples, posterior means), each k×SEG. Costs k/8
    /// NFE.
    ///
    /// The default returns `Ok(None)` — "no fused support, fall back to
    /// serial [`Denoiser::drafter_step`] calls" — so backends without
    /// fusion (and test denoisers) need no stub. [`ModelRuntime`]
    /// overrides it per exported artifact size;
    /// [`crate::drafter::DistilledDrafter`] overrides it with a natively
    /// fused KV-cached rollout that serves every `k`.
    fn drafter_rollout(
        &self,
        _k: usize,
        _x: &[f32],
        _t0: usize,
        _cond: &[f32],
        _noise: &[f32],
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        Ok(None)
    }
    /// NFE accounting.
    fn nfe(&self) -> &NfeCounter;
}

impl Denoiser for ModelRuntime {
    fn encode(&self, obs: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::encode(self, obs)
    }

    fn target_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::target_step(self, x, t, cond)
    }

    fn target_verify(&self, xs: &[f32], ts: &[f32], cond: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::target_verify(self, xs, ts, cond)
    }

    fn drafter_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::drafter_step(self, x, t, cond)
    }

    fn drafter_rollout(
        &self,
        k: usize,
        x: &[f32],
        t0: usize,
        cond: &[f32],
        noise: &[f32],
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        if self.rollout_ks().contains(&k) {
            ModelRuntime::drafter_rollout(self, k, x, t0, cond, noise).map(Some)
        } else {
            Ok(None)
        }
    }

    fn nfe(&self) -> &NfeCounter {
        &self.nfe
    }
}
