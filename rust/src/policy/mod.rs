//! The `Denoiser` abstraction: what the speculative engine needs from a
//! diffusion policy.
//!
//! The production implementation is [`crate::runtime::ModelRuntime`]
//! (PJRT executables, behind the `pjrt` feature); tests and the PPO
//! scheduler's training loop can also run against
//! [`mock::MockDenoiser`], an analytic target/drafter pair with a
//! controllable disagreement — so every algorithmic property of the
//! engine is testable without artifacts.
//!
//! Denoisers are deliberately **not** required to be `Send` (PJRT
//! handles are raw C pointers). The sharded serving fleet therefore
//! never moves a denoiser across threads: each shard worker builds its
//! own replica on its own thread through a
//! [`crate::coordinator::server::ReplicaFactory`] and owns it for the
//! lifetime of the run.

pub mod mock;

use crate::config::{EMBED_DIM, VERIFY_BATCH};
use crate::runtime::executable::SEG;
use crate::runtime::{ModelRuntime, NfeCounter};
use anyhow::{ensure, Result};

/// One request of a batched drafter wave: the borrowed per-session
/// inputs [`Denoiser::drafter_rollout`] would take, bundled so
/// [`Denoiser::drafter_rollout_many`] can advance many sessions per
/// draft step. The noise is drawn job-side from the session's own RNG
/// stream *before* the wave forms, so wave composition can never change
/// a session's bits.
#[derive(Debug)]
pub struct RolloutRequest<'a> {
    /// Draft steps requested (1..=K_MAX, already clamped by the job).
    pub k: usize,
    /// Current latent, SEG floats.
    pub x: &'a [f32],
    /// Starting timestep; the rollout covers `t0, t0-1, .., t0-k+1`.
    pub t0: usize,
    /// Conditioning vector, EMBED_DIM floats.
    pub cond: &'a [f32],
    /// Pre-drawn Gaussian noise, k×SEG floats.
    pub noise: &'a [f32],
}

/// Model evaluations used by the denoising engines.
///
/// All tensors are flat row-major `f32` slices; shapes are fixed by
/// `crate::config` (x: HORIZON×ACT_DIM, cond: EMBED_DIM).
pub trait Denoiser {
    /// Observation encoder: obs[OBS_DIM] → cond[EMBED_DIM].
    fn encode(&self, obs: &[f32]) -> Result<Vec<f32>>;
    /// Target ε-prediction at one latent/timestep. Costs 1 NFE.
    fn target_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>>;
    /// Batched target ε-prediction over VERIFY_BATCH candidates in one
    /// parallel forward pass. Costs 1 NFE.
    fn target_verify(&self, xs: &[f32], ts: &[f32], cond: &[f32]) -> Result<Vec<f32>>;
    /// Multi-request fused verification: `n` requests' verify batches in
    /// one call, each with its own conditioning vector.
    ///
    /// Layout: `xs` is n × VERIFY_BATCH × SEG, `ts` is n × VERIFY_BATCH,
    /// `conds` is n × EMBED_DIM; the output is n × VERIFY_BATCH × SEG in
    /// the same request order. Costs 1 NFE *per request* (each request's
    /// share is one parallel target forward — fusing across requests
    /// amortizes dispatch, not model evaluations), so per-request NFE
    /// accounting is independent of how many requests share a call.
    ///
    /// The default implementation loops over per-request
    /// [`Denoiser::target_verify`] calls and is bit-identical to serving
    /// the requests one at a time; backends with a multi-conditioning
    /// verify kernel can override it with a genuinely fused forward.
    /// [`mock::MockDenoiser`] overrides it with a fused evaluation;
    /// [`ModelRuntime`] uses this loop until a multi-conditioning verify
    /// artifact is exported (its compiled `target_verify` shares one cond
    /// across the batch).
    fn target_verify_many(&self, xs: &[f32], ts: &[f32], conds: &[f32]) -> Result<Vec<f32>> {
        ensure!(conds.len() % EMBED_DIM == 0, "conds len {}", conds.len());
        let n = conds.len() / EMBED_DIM;
        ensure!(xs.len() == n * VERIFY_BATCH * SEG, "xs len {}", xs.len());
        ensure!(ts.len() == n * VERIFY_BATCH, "ts len {}", ts.len());
        let mut out = Vec::with_capacity(xs.len());
        for r in 0..n {
            let eps = self.target_verify(
                &xs[r * VERIFY_BATCH * SEG..(r + 1) * VERIFY_BATCH * SEG],
                &ts[r * VERIFY_BATCH..(r + 1) * VERIFY_BATCH],
                &conds[r * EMBED_DIM..(r + 1) * EMBED_DIM],
            )?;
            out.extend_from_slice(&eps);
        }
        Ok(out)
    }
    /// Drafter ε-prediction. Costs 1/8 NFE.
    fn drafter_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>>;
    /// Fused K-step drafter rollout, if the backend supports `k`:
    /// returns (draft samples, posterior means), each k×SEG. Costs k/8
    /// NFE.
    ///
    /// The default returns `Ok(None)` — "no fused support, fall back to
    /// serial [`Denoiser::drafter_step`] calls" — so backends without
    /// fusion (and test denoisers) need no stub. [`ModelRuntime`]
    /// overrides it per exported artifact size;
    /// [`crate::drafter::DistilledDrafter`] overrides it with a natively
    /// fused KV-cached rollout that serves every `k`.
    fn drafter_rollout(
        &self,
        _k: usize,
        _x: &[f32],
        _t0: usize,
        _cond: &[f32],
        _noise: &[f32],
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        Ok(None)
    }
    /// Continuous-batched drafter rollouts: advance *every* request one
    /// denoising step per wave, sessions joining and leaving the wave
    /// at step granularity. Returns one `drafter_rollout`-shaped result
    /// per request, in request order; `None` entries fall back to the
    /// caller's serial drafter path. Costs `kᵢ`/8 NFE per request —
    /// identical to serving them one at a time.
    ///
    /// The default loops per-request [`Denoiser::drafter_rollout`],
    /// which is bit-identical to serial serving by construction.
    /// [`crate::drafter::DistilledDrafter`] overrides it with a genuine
    /// wave-stepped forward over a shared per-shard KV arena
    /// ([`crate::drafter::KvArena`]); the override keeps every
    /// request's arithmetic order equal to the serial path, so batched
    /// and serial segments stay bitwise equal.
    fn drafter_rollout_many(
        &self,
        reqs: &[RolloutRequest<'_>],
    ) -> Result<Vec<Option<(Vec<f32>, Vec<f32>)>>> {
        reqs.iter()
            .map(|r| self.drafter_rollout(r.k, r.x, r.t0, r.cond, r.noise))
            .collect()
    }
    /// Peak KV-arena block demand since this denoiser was built, when
    /// the backend batches drafts over a [`crate::drafter::KvArena`]
    /// (`None` for backends without one). Polled by the serving fleet's
    /// metrics at shard shutdown.
    fn kv_arena_high_water(&self) -> Option<usize> {
        None
    }
    /// NFE accounting.
    fn nfe(&self) -> &NfeCounter;
}

impl Denoiser for ModelRuntime {
    fn encode(&self, obs: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::encode(self, obs)
    }

    fn target_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::target_step(self, x, t, cond)
    }

    fn target_verify(&self, xs: &[f32], ts: &[f32], cond: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::target_verify(self, xs, ts, cond)
    }

    fn drafter_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        ModelRuntime::drafter_step(self, x, t, cond)
    }

    fn drafter_rollout(
        &self,
        k: usize,
        x: &[f32],
        t0: usize,
        cond: &[f32],
        noise: &[f32],
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        if self.rollout_ks().contains(&k) {
            ModelRuntime::drafter_rollout(self, k, x, t0, cond, noise).map(Some)
        } else {
            Ok(None)
        }
    }

    fn nfe(&self) -> &NfeCounter {
        &self.nfe
    }
}
