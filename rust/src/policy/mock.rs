//! Analytic mock denoiser for artifact-free testing.
//!
//! The mock "target" implements an exact linear ε-model whose reverse
//! process provably converges: ε*(x, t, cond) is the noise implied by
//! pretending the clean sample is `g(cond)` (a fixed linear readout of
//! the conditioning). The mock "drafter" is the same model plus a
//! controllable disagreement `delta(t)` — letting tests dial acceptance
//! rates from ~100% down to ~0% and assert every property of the
//! speculative engine (losslessness, NFE accounting, phase-dependent
//! acceptance) without any PJRT artifacts.

use crate::config::{ACT_DIM, EMBED_DIM, HORIZON, OBS_DIM, VERIFY_BATCH};
use crate::diffusion::DdpmSchedule;
use crate::policy::Denoiser;
use crate::runtime::NfeCounter;
use anyhow::{ensure, Result};

/// Flattened segment size.
pub const SEG: usize = HORIZON * ACT_DIM;

/// Controllable analytic target/drafter pair.
pub struct MockDenoiser {
    sched: DdpmSchedule,
    /// Per-timestep drafter disagreement added to ε (in ε units).
    pub drafter_bias: Box<dyn Fn(usize) -> f32 + Send>,
    nfe: NfeCounter,
}

impl MockDenoiser {
    /// Mock with a constant drafter disagreement.
    pub fn with_bias(bias: f32) -> Self {
        Self {
            sched: DdpmSchedule::cosine(crate::config::DIFFUSION_STEPS),
            drafter_bias: Box::new(move |_| bias),
            nfe: NfeCounter::new(),
        }
    }

    /// Mock with a timestep-dependent disagreement.
    pub fn with_bias_fn(f: impl Fn(usize) -> f32 + Send + 'static) -> Self {
        Self {
            sched: DdpmSchedule::cosine(crate::config::DIFFUSION_STEPS),
            drafter_bias: Box::new(f),
            nfe: NfeCounter::new(),
        }
    }

    /// The clean action segment implied by a conditioning vector.
    pub fn clean_action(cond: &[f32]) -> Vec<f32> {
        // Deterministic linear readout: element (h, a) mixes two cond dims.
        let mut out = vec![0.0f32; SEG];
        for h in 0..HORIZON {
            for a in 0..ACT_DIM {
                let i = h * ACT_DIM + a;
                out[i] = 0.5 * (cond[(h + a) % EMBED_DIM].tanh()
                    + cond[(3 * h + 2 * a + 1) % EMBED_DIM].tanh());
            }
        }
        out
    }

    /// ε implied by x_t if the clean sample were `clean_action(cond)`:
    /// ε = (x_t − √ᾱ·x0) / √(1−ᾱ).
    fn eps_star(&self, x: &[f32], t: usize, cond: &[f32]) -> Vec<f32> {
        let ab = self.sched.alpha_bars[t];
        let (sa, sb) = (ab.sqrt(), (1.0 - ab).sqrt().max(1e-4));
        let x0 = Self::clean_action(cond);
        (0..SEG).map(|i| (x[i] - sa * x0[i]) / sb).collect()
    }
}

impl Denoiser for MockDenoiser {
    fn encode(&self, obs: &[f32]) -> Result<Vec<f32>> {
        ensure!(obs.len() == OBS_DIM);
        // Deterministic expansion of the observation.
        Ok((0..EMBED_DIM).map(|i| (obs[i % OBS_DIM] * (1.0 + i as f32 * 0.01)).sin()).collect())
    }

    fn target_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        self.nfe.count_target();
        Ok(self.eps_star(x, t, cond))
    }

    fn target_verify(&self, xs: &[f32], ts: &[f32], cond: &[f32]) -> Result<Vec<f32>> {
        ensure!(xs.len() == VERIFY_BATCH * SEG);
        self.nfe.count_target();
        let mut out = Vec::with_capacity(VERIFY_BATCH * SEG);
        for b in 0..VERIFY_BATCH {
            let x = &xs[b * SEG..(b + 1) * SEG];
            out.extend(self.eps_star(x, ts[b] as usize, cond));
        }
        Ok(out)
    }

    fn target_verify_many(&self, xs: &[f32], ts: &[f32], conds: &[f32]) -> Result<Vec<f32>> {
        // Genuinely fused layout: every request's candidates evaluated in
        // one pass over the concatenated inputs, one conditioning vector
        // per request. Arithmetic is identical to per-request
        // `target_verify`, so fused serving is bit-identical to serial
        // serving; NFE stays 1 per request.
        ensure!(conds.len() % EMBED_DIM == 0, "conds len {}", conds.len());
        let n = conds.len() / EMBED_DIM;
        ensure!(xs.len() == n * VERIFY_BATCH * SEG, "xs len {}", xs.len());
        ensure!(ts.len() == n * VERIFY_BATCH, "ts len {}", ts.len());
        let mut out = Vec::with_capacity(xs.len());
        for r in 0..n {
            self.nfe.count_target();
            let cond = &conds[r * EMBED_DIM..(r + 1) * EMBED_DIM];
            for b in 0..VERIFY_BATCH {
                let c = r * VERIFY_BATCH + b;
                let x = &xs[c * SEG..(c + 1) * SEG];
                out.extend(self.eps_star(x, ts[c] as usize, cond));
            }
        }
        Ok(out)
    }

    fn drafter_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        self.nfe.count_drafter(1);
        let bias = (self.drafter_bias)(t);
        Ok(self.eps_star(x, t, cond).iter().map(|e| e + bias).collect())
    }

    // drafter_rollout: trait default (Ok(None)) — the mock has no fused
    // artifacts, so the engine falls back to serial drafter steps.

    fn nfe(&self) -> &NfeCounter {
        &self.nfe
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DIFFUSION_STEPS;
    use crate::util::Rng;

    /// Full serial reverse diffusion under the mock target recovers the
    /// clean action — the mock is a *consistent* denoiser.
    #[test]
    fn mock_target_reverse_process_converges() {
        let m = MockDenoiser::with_bias(0.0);
        let obs = vec![0.3f32; OBS_DIM];
        let cond = m.encode(&obs).unwrap();
        let clean = MockDenoiser::clean_action(&cond);
        let sched = DdpmSchedule::cosine(DIFFUSION_STEPS);
        let mut rng = Rng::seed_from_u64(0);
        let mut x = rng.normal_vec(SEG);
        for t in (0..DIFFUSION_STEPS).rev() {
            let eps = m.target_step(&x, t, &cond).unwrap();
            let xi = rng.normal_vec(SEG);
            let (next, _) = sched.step(t, &x, &eps, &xi);
            x = next;
        }
        let err: f32 =
            x.iter().zip(&clean).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 0.15, "max err {err}");
    }

    #[test]
    fn drafter_bias_shifts_eps() {
        let m = MockDenoiser::with_bias(0.5);
        let cond = m.encode(&vec![0.1; OBS_DIM]).unwrap();
        let x = vec![0.2f32; SEG];
        let et = m.target_step(&x, 50, &cond).unwrap();
        let ed = m.drafter_step(&x, 50, &cond).unwrap();
        for i in 0..SEG {
            assert!((ed[i] - et[i] - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn verify_batch_matches_single_steps() {
        let m = MockDenoiser::with_bias(0.0);
        let cond = m.encode(&vec![0.4; OBS_DIM]).unwrap();
        let mut rng = Rng::seed_from_u64(1);
        let mut xs = Vec::new();
        let mut ts = Vec::new();
        for b in 0..VERIFY_BATCH {
            xs.extend(rng.normal_vec(SEG));
            ts.push((b * 5 % DIFFUSION_STEPS) as f32);
        }
        let batch = m.target_verify(&xs, &ts, &cond).unwrap();
        for b in [0, 7, VERIFY_BATCH - 1] {
            let single =
                m.target_step(&xs[b * SEG..(b + 1) * SEG], ts[b] as usize, &cond).unwrap();
            assert_eq!(&batch[b * SEG..(b + 1) * SEG], &single[..]);
        }
    }

    #[test]
    fn verify_many_matches_per_request_verify() {
        let m = MockDenoiser::with_bias(0.0);
        let mut rng = Rng::seed_from_u64(5);
        let conds: Vec<Vec<f32>> = (0..3)
            .map(|i| m.encode(&vec![0.1 + 0.2 * i as f32; OBS_DIM]).unwrap())
            .collect();
        let mut xs = Vec::new();
        let mut ts = Vec::new();
        let mut flat_conds = Vec::new();
        for cond in &conds {
            flat_conds.extend_from_slice(cond);
            for b in 0..VERIFY_BATCH {
                xs.extend(rng.normal_vec(SEG));
                ts.push((b * 3 % DIFFUSION_STEPS) as f32);
            }
        }
        let fused = m.target_verify_many(&xs, &ts, &flat_conds).unwrap();
        assert_eq!(fused.len(), 3 * VERIFY_BATCH * SEG);
        for (r, cond) in conds.iter().enumerate() {
            let lo = r * VERIFY_BATCH * SEG;
            let hi = (r + 1) * VERIFY_BATCH * SEG;
            let single = m
                .target_verify(
                    &xs[lo..hi],
                    &ts[r * VERIFY_BATCH..(r + 1) * VERIFY_BATCH],
                    cond,
                )
                .unwrap();
            assert_eq!(&fused[lo..hi], &single[..], "request {r} must be bit-identical");
        }
    }

    #[test]
    fn nfe_is_counted() {
        let m = MockDenoiser::with_bias(0.0);
        let cond = m.encode(&vec![0.0; OBS_DIM]).unwrap();
        let x = vec![0.0f32; SEG];
        m.target_step(&x, 10, &cond).unwrap();
        m.drafter_step(&x, 10, &cond).unwrap();
        assert_eq!(m.nfe().nfe(), 1.125);
    }
}
