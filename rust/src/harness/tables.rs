//! Table regeneration: one function per table in the paper's evaluation.
//!
//! Paired "a / b" numbers in the paper are two evaluation protocols; we
//! reproduce the pairing with two independent seed groups.

use crate::baselines::make_generator;
use crate::config::{DemoStyle, Method, SpecParams, Task};
use crate::envs::make_env;
use crate::harness::episode::{run_episode, EpisodeResult};
use crate::policy::Denoiser;
use crate::scheduler::{SchedulerPolicy, ServingHook};
use anyhow::Result;

/// Aggregated statistics for one (method, task, style, seed-group) cell.
#[derive(Debug, Clone)]
pub struct Cell {
    /// Success rate in percent.
    pub success_pct: f64,
    /// Mean continuous score in percent (coverage tasks).
    pub score_pct: f64,
    /// Mean NFE per segment (vanilla = 100).
    pub nfe_pct: f64,
    /// NFE-based speedup over vanilla DP.
    pub speedup: f64,
    /// Mean drafts per segment.
    pub drafts: f64,
    /// Draft acceptance rate in percent.
    pub acceptance_pct: f64,
    /// Mean per-segment denoising latency (seconds).
    pub latency_secs: f64,
    /// Control frequency (Hz).
    pub freq_hz: f64,
    /// Multi-stage sub-scores: fraction of episodes reaching >= x stages
    /// (Kitchen p1..p4 / BlockPush p1..p2).
    pub stage_pct: Vec<f64>,
}

/// Evaluation options for a cell.
#[derive(Debug, Clone)]
pub struct EvalOpts {
    /// Episodes per cell.
    pub episodes: usize,
    /// Base seed of this seed group.
    pub seed: u64,
    /// Trained scheduler policy (None = fixed parameters).
    pub scheduler: Option<SchedulerPolicy>,
    /// Override for TS-DP's fixed parameters (Table 4 ablations).
    pub fixed_params: Option<SpecParams>,
}

impl Default for EvalOpts {
    fn default() -> Self {
        Self { episodes: 10, seed: 0, scheduler: None, fixed_params: None }
    }
}

/// Run all episodes for one cell and aggregate.
pub fn eval_cell(
    den: &dyn Denoiser,
    task: Task,
    style: DemoStyle,
    method: Method,
    opts: &EvalOpts,
) -> Result<Cell> {
    let mut results: Vec<EpisodeResult> = Vec::with_capacity(opts.episodes);
    for ep in 0..opts.episodes {
        let mut env = make_env(task, style);
        let mut generator = make_generator(method);
        if let (Method::TsDp, Some(p)) = (method, opts.fixed_params) {
            generator.set_params(p);
        }
        let seed = opts.seed ^ ((ep as u64 + 1) << 8) ^ (task.index() as u64) << 40;
        let result = match (&opts.scheduler, method) {
            (Some(policy), Method::TsDp) => {
                let mut hook = ServingHook::new(policy.clone());
                run_episode(den, env.as_mut(), generator.as_mut(), style, seed, Some(&mut hook))?
            }
            _ => run_episode(den, env.as_mut(), generator.as_mut(), style, seed, None)?,
        };
        results.push(result);
    }
    Ok(aggregate(task, &results))
}

/// Number of stage metrics a task reports (Kitchen 4, BlockPush 2).
pub fn stage_count(task: Task) -> usize {
    match task {
        Task::Kitchen => 4,
        Task::BlockPush => 2,
        _ => 0,
    }
}

fn aggregate(task: Task, results: &[EpisodeResult]) -> Cell {
    let n = results.len().max(1) as f64;
    let success = results.iter().filter(|r| r.success).count() as f64 / n;
    let score = results.iter().map(|r| r.score as f64).sum::<f64>() / n;
    let nfe = results.iter().map(|r| r.nfe_percent()).sum::<f64>() / n;
    let drafts = results
        .iter()
        .map(|r| r.drafts() as f64 / r.segments.len().max(1) as f64)
        .sum::<f64>()
        / n;
    let acc = results.iter().map(|r| r.acceptance_rate()).sum::<f64>() / n;
    let latency = results.iter().map(|r| r.latency_secs()).sum::<f64>() / n;
    let freq = results.iter().map(|r| r.frequency_hz()).sum::<f64>() / n;
    // Stage fractions from the continuous score: score >= x/stages.
    let stages = stage_count(task);
    let stage_pct = (1..=stages)
        .map(|x| {
            let threshold = x as f32 / stages as f32 - 1e-4;
            results.iter().filter(|r| r.score >= threshold).count() as f64 / n * 100.0
        })
        .collect();
    Cell {
        success_pct: success * 100.0,
        score_pct: score * 100.0,
        nfe_pct: nfe,
        speedup: if nfe > 0.0 { 100.0 / nfe } else { 0.0 },
        drafts,
        acceptance_pct: acc * 100.0,
        latency_secs: latency,
        freq_hz: freq,
        stage_pct,
    }
}

/// Format a paired "a / b" cell.
pub fn paired(a: f64, b: f64, width: usize, decimals: usize) -> String {
    format!("{:>w$.d$} / {:<w$.d$}", a, b, w = width, d = decimals)
}

/// Tables 1 & 2: per-task success + NFE + speed for every method.
pub fn success_table(
    den: &dyn Denoiser,
    style: DemoStyle,
    tasks: &[Task],
    opts: &[EvalOpts; 2],
) -> Result<String> {
    let mut out = String::new();
    let title = match style {
        DemoStyle::Ph => "Table 1 — Proficient Human (PH)",
        DemoStyle::Mh => "Table 2 — Mixed Human (MH)",
    };
    out.push_str(&format!("{title}\n"));
    out.push_str(&format!("{:<22}", "Method"));
    for t in tasks {
        out.push_str(&format!("{:>16}", t.name()));
    }
    out.push_str(&format!("{:>16}{:>16}{:>14}\n", "AVG", "NFE(%)", "Speed x"));
    for method in Method::ALL {
        out.push_str(&format!("{:<22}", method.label()));
        // Evaluate each (task, group) cell exactly once.
        let mut cells: Vec<[Cell; 2]> = Vec::with_capacity(tasks.len());
        for t in tasks {
            let a = eval_cell(den, *t, style, method, &opts[0])?;
            let b = eval_cell(den, *t, style, method, &opts[1])?;
            cells.push([a, b]);
        }
        let mut avg = [0.0f64; 2];
        let mut nfe = [0.0f64; 2];
        for (t, c) in tasks.iter().zip(&cells) {
            let val = |cell: &Cell| {
                if t.continuous_outcome() {
                    cell.score_pct
                } else {
                    cell.success_pct
                }
            };
            out.push_str(&format!("{:>16}", paired(val(&c[0]), val(&c[1]), 5, 0)));
            for g in 0..2 {
                avg[g] += val(&c[g]) / tasks.len() as f64;
                nfe[g] += c[g].nfe_pct / tasks.len() as f64;
            }
        }
        out.push_str(&format!("{:>16}", paired(avg[0], avg[1], 5, 0)));
        out.push_str(&format!("{:>16}", paired(nfe[0], nfe[1], 5, 0)));
        let sp = |n: f64| if n > 0.0 { 100.0 / n } else { 0.0 };
        out.push_str(&format!("{:>14}\n", paired(sp(nfe[0]), sp(nfe[1]), 4, 2)));
    }
    Ok(out)
}

/// Table 3: multi-stage Kitchen + BlockPush with per-stage success.
pub fn multistage_table(den: &dyn Denoiser, opts: &[EvalOpts; 2]) -> Result<String> {
    let mut out = String::new();
    out.push_str("Table 3 — Multi-stage (Kitchen & Block Push)\n");
    out.push_str(&format!(
        "{:<22}{:>14}{:>14}{:>14}{:>14}{:>14}{:>14}{:>14}{:>12}\n",
        "Method", "BP_p1", "BP_p2", "Kit_p1", "Kit_p2", "Kit_p3", "Kit_p4", "NFE(%)", "Speed x"
    ));
    for method in Method::ALL {
        out.push_str(&format!("{:<22}", method.label()));
        let mut cells = Vec::new();
        for o in opts {
            let bp = eval_cell(den, Task::BlockPush, DemoStyle::Ph, method, o)?;
            let kit = eval_cell(den, Task::Kitchen, DemoStyle::Ph, method, o)?;
            cells.push((bp, kit));
        }
        for stage in 0..2 {
            out.push_str(&format!(
                "{:>14}",
                paired(cells[0].0.stage_pct[stage], cells[1].0.stage_pct[stage], 4, 0)
            ));
        }
        for stage in 0..4 {
            out.push_str(&format!(
                "{:>14}",
                paired(cells[0].1.stage_pct[stage], cells[1].1.stage_pct[stage], 4, 0)
            ));
        }
        let nfe: Vec<f64> =
            cells.iter().map(|(bp, kit)| (bp.nfe_pct + kit.nfe_pct) / 2.0).collect();
        out.push_str(&format!("{:>14}", paired(nfe[0], nfe[1], 4, 0)));
        let sp = |n: f64| if n > 0.0 { 100.0 / n } else { 0.0 };
        out.push_str(&format!("{:>12}\n", paired(sp(nfe[0]), sp(nfe[1]), 4, 2)));
    }
    Ok(out)
}

/// Table 4: fixed-K ablation vs the adaptive scheduler.
pub fn ablation_table(
    den: &dyn Denoiser,
    scheduler: Option<SchedulerPolicy>,
    episodes: usize,
    seed: u64,
) -> Result<String> {
    let tasks = [Task::Lift, Task::Can, Task::Square, Task::Transport];
    let mut out = String::new();
    out.push_str("Table 4 — Fixed K vs adaptive scheduler (PH)\n");
    out.push_str(&format!(
        "{:<10}{:>8}{:>8}{:>8}{:>11}{:>8}{:>10}\n",
        "Config", "Lift", "Can", "Square", "Transport", "AVG", "Speed x"
    ));
    let run_row = |label: &str,
                       params: Option<SpecParams>,
                       sched: Option<SchedulerPolicy>|
     -> Result<String> {
        let opts = EvalOpts { episodes, seed, scheduler: sched, fixed_params: params };
        let mut row = format!("{:<10}", label);
        let mut avg = 0.0;
        let mut nfe = 0.0;
        for t in tasks {
            let cell = eval_cell(den, t, DemoStyle::Ph, Method::TsDp, &opts)?;
            row.push_str(&format!("{:>8.0}", cell.success_pct));
            avg += cell.success_pct / tasks.len() as f64;
            nfe += cell.nfe_pct / tasks.len() as f64;
        }
        row.push_str(&format!("{:>8.0}{:>10.2}\n", avg, 100.0 / nfe.max(1e-9)));
        Ok(row)
    };
    // The paper sweeps K in {10, 25, 40}; our verify batch caps K at
    // K_MAX=16, so the sweep is rescaled to {4, 10, 16} — same
    // conservative/moderate/aggressive trade-off axis (DESIGN.md §2).
    for k in [4usize, 10, crate::config::K_MAX] {
        out.push_str(&run_row(&format!("K={k}"), Some(SpecParams::fixed_k(k)), None)?);
    }
    out.push_str(&run_row("TS-DP", None, scheduler)?);
    Ok(out)
}

/// Table 5: frequency / latency.
pub fn latency_table(den: &dyn Denoiser, episodes: usize, seed: u64) -> Result<String> {
    let tasks = [Task::Lift, Task::Can, Task::Square, Task::Transport];
    let mut out = String::new();
    out.push_str("Table 5 — Frequency (Hz) / Latency (s)\n");
    out.push_str(&format!("{:<22}", "Method"));
    for t in tasks {
        out.push_str(&format!("{:>20}", t.name()));
    }
    out.push_str(&format!("{:>20}\n", "AVG"));
    for method in [Method::Vanilla, Method::TsDp] {
        out.push_str(&format!("{:<22}", method.label()));
        let mut freq_avg = 0.0;
        let mut lat_avg = 0.0;
        for t in tasks {
            let opts = EvalOpts { episodes, seed, ..Default::default() };
            let cell = eval_cell(den, t, DemoStyle::Ph, method, &opts)?;
            out.push_str(&format!(
                "{:>12.2} / {:<5.3}",
                cell.freq_hz, cell.latency_secs
            ));
            freq_avg += cell.freq_hz / tasks.len() as f64;
            lat_avg += cell.latency_secs / tasks.len() as f64;
        }
        out.push_str(&format!("{:>12.2} / {:<5.3}\n", freq_avg, lat_avg));
    }
    Ok(out)
}

/// Supplement tables S1–S3: NFE / speed / draft count / acceptance rate
/// per task.
pub fn supplement_table(
    den: &dyn Denoiser,
    which: &str,
    opts: &[EvalOpts; 2],
) -> Result<String> {
    let (title, tasks, style): (&str, Vec<Task>, DemoStyle) = match which {
        "s1" => (
            "Supp. Table 1 — PH metrics",
            vec![Task::Lift, Task::Can, Task::Square, Task::Transport, Task::ToolHang, Task::PushT],
            DemoStyle::Ph,
        ),
        "s2" => (
            "Supp. Table 2 — MH metrics",
            vec![Task::Lift, Task::Can, Task::Square, Task::Transport],
            DemoStyle::Mh,
        ),
        "s3" => (
            "Supp. Table 3 — multi-stage metrics",
            vec![Task::BlockPush, Task::Kitchen],
            DemoStyle::Ph,
        ),
        other => anyhow::bail!("unknown supplement table '{other}'"),
    };
    let mut out = format!("{title} (TS-DP)\n{:<18}", "Metric");
    for t in &tasks {
        out.push_str(&format!("{:>18}", t.name()));
    }
    out.push_str(&format!("{:>18}\n", "AVG"));
    let mut cells: Vec<[Cell; 2]> = Vec::new();
    for t in &tasks {
        let a = eval_cell(den, *t, style, Method::TsDp, &opts[0])?;
        let b = eval_cell(den, *t, style, Method::TsDp, &opts[1])?;
        cells.push([a, b]);
    }
    let metric = |out: &mut String, name: &str, f: &dyn Fn(&Cell) -> f64, dec: usize| {
        out.push_str(&format!("{:<18}", name));
        let mut avg = [0.0f64; 2];
        for c in &cells {
            out.push_str(&format!("{:>18}", paired(f(&c[0]), f(&c[1]), 6, dec)));
            avg[0] += f(&c[0]) / cells.len() as f64;
            avg[1] += f(&c[1]) / cells.len() as f64;
        }
        out.push_str(&format!("{:>18}\n", paired(avg[0], avg[1], 6, dec)));
    };
    metric(&mut out, "NFE", &|c| c.nfe_pct, 1);
    metric(&mut out, "Speed (x)", &|c| c.speedup, 2);
    metric(&mut out, "Draft count", &|c| c.drafts, 1);
    metric(&mut out, "Acceptance (%)", &|c| c.acceptance_pct, 1);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::mock::MockDenoiser;

    #[test]
    fn eval_cell_reports_consistent_metrics() {
        let den = MockDenoiser::with_bias(0.05);
        let opts = EvalOpts { episodes: 2, ..Default::default() };
        let cell = eval_cell(&den, Task::Lift, DemoStyle::Ph, Method::TsDp, &opts).unwrap();
        assert!(cell.nfe_pct > 0.0 && cell.nfe_pct < 100.0);
        assert!((cell.speedup - 100.0 / cell.nfe_pct).abs() < 1e-9);
        assert!(cell.acceptance_pct >= 0.0 && cell.acceptance_pct <= 100.0);
    }

    #[test]
    fn stage_metrics_for_multistage_tasks() {
        let den = MockDenoiser::with_bias(0.05);
        let opts = EvalOpts { episodes: 2, ..Default::default() };
        let cell =
            eval_cell(&den, Task::Kitchen, DemoStyle::Ph, Method::Vanilla, &opts).unwrap();
        assert_eq!(cell.stage_pct.len(), 4);
        // p1 >= p2 >= p3 >= p4 by construction.
        for w in cell.stage_pct.windows(2) {
            assert!(w[0] >= w[1] - 1e-9, "{:?}", cell.stage_pct);
        }
    }

    #[test]
    fn paired_formatting() {
        let s = paired(85.0, 80.0, 5, 0);
        assert!(s.contains('/'));
        assert!(s.contains("85"));
    }
}
