//! Evaluation harness: episode runner, table/figure regeneration, CLI.

pub mod cli;
pub mod episode;
pub mod figures;
pub mod scenarios;
pub mod tables;

pub use episode::{run_episode, DecisionHook, EpisodeResult, SegmentMeta, SegmentOutcome};
