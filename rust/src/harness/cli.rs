//! CLI entry points for episodes, tables and figures.

use crate::baselines::make_generator;
use crate::config::{DemoStyle, Method, Task};
use crate::envs::make_env;
use crate::harness::episode::run_episode;
use crate::harness::{figures, tables};
use crate::runtime::ModelRuntime;
use crate::scheduler::{SchedulerPolicy, ServingHook};
use crate::util::cli::Args;
use anyhow::{Context, Result};
use std::path::PathBuf;

fn load_runtime(args: &Args) -> Result<ModelRuntime> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    ModelRuntime::load(&dir)
        .with_context(|| format!("loading artifacts from {} (run `make artifacts`)", dir.display()))
}

fn load_scheduler(args: &Args) -> Option<SchedulerPolicy> {
    let path = PathBuf::from(
        args.get_or("scheduler-policy", "artifacts/scheduler_policy.json"),
    );
    SchedulerPolicy::load(&path).ok()
}

/// `ts-dp episode --task T --style ph|mh [--method M] [--adaptive]
/// [--drafter FILE [--drafter-dtype f32|int8]] [--backend
/// artifacts|mock]`.
pub fn cmd_episode(args: &Args) -> Result<()> {
    use crate::coordinator::cli::{backend_choice, drafter_from_args, drafter_kind, with_drafter};
    let task = Task::parse(&args.get_or("task", "lift")).context("unknown --task")?;
    let style = DemoStyle::parse(&args.get_or("style", "ph")).context("bad --style")?;
    let method = Method::parse(&args.get_or("method", "ts_dp")).context("bad --method")?;
    let seed = args.get_u64("seed", 0)?;
    // Same backend selection + drafter swap as the serving CLI: eval
    // runs see exactly the denoiser stack `serve --drafter` serves.
    let drafter = drafter_from_args(args)?;
    let den = with_drafter(backend_choice(args)?.build()?, &drafter);
    let mut env = make_env(task, style);
    let mut generator = make_generator(method);
    let result = if args.has_flag("adaptive") && method == Method::TsDp {
        let policy = load_scheduler(args)
            .context("--adaptive needs a trained scheduler policy (run train-scheduler)")?;
        let mut hook = ServingHook::new(policy);
        run_episode(den.as_ref(), env.as_mut(), generator.as_mut(), style, seed, Some(&mut hook))?
    } else {
        run_episode(den.as_ref(), env.as_mut(), generator.as_mut(), style, seed, None)?
    };
    let drafter_kind = drafter_kind(&drafter);
    println!(
        "task={} style={} method={} drafter={}",
        task.name(),
        style.name(),
        method.name(),
        drafter_kind.name()
    );
    println!("success={} score={:.2} steps={}", result.success, result.score, result.steps);
    println!(
        "segments={} nfe/segment={:.1} speed_x={:.2}",
        result.segments.len(),
        result.nfe_percent(),
        100.0 / result.nfe_percent().max(1e-9)
    );
    println!(
        "drafts={} accepted={} acceptance={:.1}%",
        result.drafts(),
        result.accepted(),
        result.acceptance_rate() * 100.0
    );
    println!(
        "latency={:.4}s/segment frequency={:.2}Hz",
        result.latency_secs(),
        result.frequency_hz()
    );
    Ok(())
}

/// `ts-dp table --id 1|2|3|4|5|s1|s2|s3`.
pub fn cmd_table(args: &Args) -> Result<()> {
    let id = args.get_or("id", "1");
    let episodes = args.get_usize("episodes", 10)?;
    let seed = args.get_u64("seed", 0)?;
    let den = load_runtime(args)?;
    let scheduler = load_scheduler(args);
    let opts = [
        tables::EvalOpts {
            episodes,
            seed,
            scheduler: scheduler.clone(),
            fixed_params: None,
        },
        tables::EvalOpts {
            episodes,
            seed: seed ^ 0x5eed_0002,
            scheduler: scheduler.clone(),
            fixed_params: None,
        },
    ];
    let text = match id.as_str() {
        "1" => {
            let tasks = [
                Task::Lift,
                Task::Can,
                Task::Square,
                Task::Transport,
                Task::ToolHang,
                Task::PushT,
            ];
            tables::success_table(&den, DemoStyle::Ph, &tasks, &opts)?
        }
        "2" => {
            let tasks = [Task::Lift, Task::Can, Task::Square, Task::Transport];
            tables::success_table(&den, DemoStyle::Mh, &tasks, &opts)?
        }
        "3" => tables::multistage_table(&den, &opts)?,
        "4" => tables::ablation_table(&den, scheduler, episodes, seed)?,
        "5" => tables::latency_table(&den, episodes, seed)?,
        s @ ("s1" | "s2" | "s3") => tables::supplement_table(&den, s, &opts)?,
        other => anyhow::bail!("unknown table id '{other}'"),
    };
    println!("{text}");
    if let Some(out) = args.get("out") {
        std::fs::write(out, text)?;
        println!("(written to {out})");
    }
    Ok(())
}

/// `ts-dp figure --id 3|4|5|6 [--out-dir DIR]`.
pub fn cmd_figure(args: &Args) -> Result<()> {
    let id = args.get_or("id", "3");
    let out_dir = PathBuf::from(args.get_or("out-dir", "results/figures"));
    std::fs::create_dir_all(&out_dir)?;
    let episodes = args.get_usize("episodes", 3)?;
    let seed = args.get_u64("seed", 0)?;
    let den = load_runtime(args)?;
    match id.as_str() {
        "3" => figures::figure3(&den, &out_dir, episodes, seed)?,
        "4" => figures::figure4(&den, &out_dir, seed)?,
        "5" => {
            let policy = load_scheduler(args)
                .context("figure 5 needs a trained scheduler policy")?;
            figures::figure5(&den, &policy, &out_dir, seed)?;
        }
        "6" => {
            let policy = load_scheduler(args);
            figures::figure6(&den, policy.as_ref(), &out_dir, seed)?;
        }
        other => anyhow::bail!("unknown figure id '{other}'"),
    }
    println!("wrote figure {id} CSVs to {}", out_dir.display());
    Ok(())
}
