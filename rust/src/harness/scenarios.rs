//! Canned evaluation scenarios shared by integration tests and benches.
//!
//! `tests/online_adapt.rs` *asserts* the frozen→adapted improvement on
//! this scenario and `benches/speculative.rs` *reports* it; building
//! both from one constructor keeps the pinned test and the printed
//! bench measuring the same thing.

use crate::config::{Method, Task};
use crate::coordinator::qos::QosClass;
use crate::coordinator::workload::SessionSpec;
use crate::policy::mock::MockDenoiser;
use crate::scheduler::SchedulerPolicy;
use crate::util::Rng;

/// Mock denoiser whose drafter disagrees strongly with the target in
/// the early high-noise phase (t ≥ 80) and barely at all later — a
/// phase-dependent difficulty profile with a clearly learnable optimal
/// schedule (short early horizons, long mid/late ones).
pub fn phase_biased_mock() -> MockDenoiser {
    MockDenoiser::with_bias_fn(|t| if t >= 80 { 0.5 } else { 0.02 })
}

/// A scheduler policy deliberately *mis*-adapted to
/// [`phase_biased_mock`]: long draft horizons everywhere, a strict
/// acceptance threshold, and a narrow acceptance σ, so early drafts get
/// rejected wholesale. Leaves headroom in every action dimension
/// (shorten k_early, relax λ, widen σ) for the online learner to find.
pub fn misadapted_scheduler() -> SchedulerPolicy {
    let mut rng = Rng::seed_from_u64(0xbad0_5eed);
    let mut p = SchedulerPolicy::init(&mut rng);
    // Raw-action order: k_early, k_mid, k_late, lambda, sigma_scale.
    let bias = [2.0f32, 2.0, 2.0, 2.0, -2.0];
    for (b, v) in p.pi.layers.last_mut().unwrap().b.iter_mut().zip(bias) {
        *b = v;
    }
    p
}

/// The canned overload mix shared by `tests/qos_serving.rs` (which
/// *asserts* that QoS beats the FIFO baseline past saturation) and
/// `benches/qos.rs` (which *reports* it, into `BENCH_qos.json`): equal
/// thirds of realtime TS-DP with a tight deadline, interactive TS-DP
/// with a loose one, and deadline-free batch vanilla — three classes
/// contending for one server.
///
/// Deadlines are parameters (not constants) because the right tightness
/// depends on the measured service time of the machine running the
/// scenario: callers calibrate with
/// [`crate::coordinator::workload::estimate_service_secs`] and pass
/// e.g. 4× the unloaded service time for realtime.
pub fn overload_stream(rt_deadline_ms: u64, interactive_deadline_ms: u64) -> Vec<SessionSpec> {
    vec![
        SessionSpec::new(Task::Lift, Method::TsDp)
            .with_qos(QosClass::Realtime)
            .with_deadline_ms(rt_deadline_ms),
        SessionSpec::new(Task::Lift, Method::TsDp)
            .with_deadline_ms(interactive_deadline_ms),
        SessionSpec::new(Task::Lift, Method::Vanilla).with_qos(QosClass::Batch),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::K_MAX;
    use crate::scheduler::features::FEAT_DIM;

    #[test]
    fn overload_stream_spans_the_three_classes() {
        let stream = overload_stream(40, 160);
        assert_eq!(stream.len(), 3);
        let classes: Vec<QosClass> = stream.iter().map(|s| s.qos).collect();
        assert!(classes.contains(&QosClass::Realtime));
        assert!(classes.contains(&QosClass::Interactive));
        assert!(classes.contains(&QosClass::Batch));
        assert_eq!(stream[0].deadline_ms, Some(40));
        assert_eq!(stream[1].deadline_ms, Some(160));
        assert_eq!(stream[2].deadline_ms, None, "batch is deadline-free");
    }

    #[test]
    fn misadapted_scheduler_means_what_it_says() {
        let p = misadapted_scheduler();
        let params =
            SchedulerPolicy::params_from_raw(&p.act_mean(&vec![0.1; FEAT_DIM]));
        assert!(params.stages.k_early > K_MAX / 2, "long early horizon");
        assert!(params.lambda > 0.1, "strict threshold");
        assert!(params.sigma_scale < 3.0, "narrow acceptance sigma");
    }
}
