//! Episode runner: the closed loop of environment ↔ policy ↔ speculative
//! engine, with optional per-segment scheduler decisions.
//!
//! This is the paper's Fig. 2 loop: each control round encodes the
//! observation, (optionally) asks the scheduler for speculative
//! parameters, generates an action segment by (speculative) denoising,
//! and executes the first EXEC_STEPS actions in the environment.

use crate::baselines::Generator;
use crate::config::{DemoStyle, Method, SpecParams, Task, ACT_DIM, EXEC_STEPS, HORIZON};
use crate::envs::Env;
use crate::policy::Denoiser;
use crate::scheduler::features::{features, FeatureState};
use crate::speculative::SegmentTrace;
use crate::util::Rng;
use anyhow::Result;

/// Per-segment metadata (figures + scheduler feedback).
#[derive(Debug, Clone)]
pub struct SegmentMeta {
    /// Env step index at which the segment was generated.
    pub env_step: usize,
    /// Env phase at generation time.
    pub phase: usize,
    /// Mean end-effector speed over the executed steps.
    pub ee_speed: f32,
    /// Drafts proposed during the segment.
    pub drafts: usize,
    /// Drafts accepted.
    pub accepted: usize,
    /// NFE consumed.
    pub nfe: f64,
    /// Wall-clock seconds for denoising this segment.
    pub wall_secs: f64,
    /// Parameters in force (scheduler output or fixed).
    pub params: SpecParams,
}

/// Outcome bundle passed to [`DecisionHook::post_segment`].
#[derive(Debug, Clone)]
pub struct SegmentOutcome<'a> {
    /// The segment's metadata.
    pub meta: &'a SegmentMeta,
    /// Episode finished with this segment.
    pub done: bool,
    /// Success at this point.
    pub success: bool,
    /// Continuous score at this point.
    pub score: f32,
    /// Task identity.
    pub task: Task,
    /// Env step limit (Eq. 15's T_max).
    pub t_max: usize,
}

/// Scheduler integration point: decide parameters before each segment,
/// observe the outcome after.
pub trait DecisionHook {
    /// Parameters for the upcoming segment.
    fn decide(&mut self, feat: &[f32]) -> SpecParams;
    /// Outcome feedback (reward computation for PPO, bookkeeping for
    /// serving).
    fn post_segment(&mut self, outcome: &SegmentOutcome<'_>);
    /// Episode boundary: the env finished (or was cut off at its step
    /// limit). Experience-collecting hooks close out and flush the
    /// episode's transitions here; the default is a no-op. Called by
    /// [`run_episode`] and by the serving session driver after every
    /// episode.
    fn finish_episode(&mut self) {}
}

/// Result of one full episode.
#[derive(Debug, Clone)]
pub struct EpisodeResult {
    /// Task identity.
    pub task: Task,
    /// Demo style the env was configured with.
    pub style: DemoStyle,
    /// Generation method.
    pub method: Method,
    /// Binary success.
    pub success: bool,
    /// Continuous score in [0, 1].
    pub score: f32,
    /// Env steps taken.
    pub steps: usize,
    /// Total NFE across segments.
    pub nfe: f64,
    /// Total denoising wall-clock (seconds).
    pub wall_secs: f64,
    /// Per-segment metadata.
    pub segments: Vec<SegmentMeta>,
    /// Full speculative traces (per segment; empty rounds for baselines
    /// that do not speculate).
    pub traces: Vec<SegmentTrace>,
}

impl EpisodeResult {
    /// Total drafts over the episode.
    pub fn drafts(&self) -> usize {
        self.segments.iter().map(|s| s.drafts).sum()
    }

    /// Total accepted drafts.
    pub fn accepted(&self) -> usize {
        self.segments.iter().map(|s| s.accepted).sum()
    }

    /// Draft acceptance rate.
    pub fn acceptance_rate(&self) -> f64 {
        let d = self.drafts();
        if d == 0 {
            0.0
        } else {
            self.accepted() as f64 / d as f64
        }
    }

    /// Mean NFE per segment, as a percentage of vanilla DP's 100.
    pub fn nfe_percent(&self) -> f64 {
        if self.segments.is_empty() {
            return 0.0;
        }
        self.nfe / self.segments.len() as f64
    }

    /// Control frequency in Hz implied by the mean segment latency and
    /// EXEC_STEPS actions per segment (paper Table 5).
    pub fn frequency_hz(&self) -> f64 {
        if self.segments.is_empty() || self.wall_secs == 0.0 {
            return 0.0;
        }
        let per_segment = self.wall_secs / self.segments.len() as f64;
        EXEC_STEPS as f64 / per_segment
    }

    /// Mean per-segment denoising latency (seconds).
    pub fn latency_secs(&self) -> f64 {
        if self.segments.is_empty() {
            return 0.0;
        }
        self.wall_secs / self.segments.len() as f64
    }
}

/// Run one episode.
pub fn run_episode(
    den: &dyn Denoiser,
    env: &mut dyn Env,
    generator: &mut dyn Generator,
    style: DemoStyle,
    seed: u64,
    mut hook: Option<&mut dyn DecisionHook>,
) -> Result<EpisodeResult> {
    let mut env_rng = Rng::seed_from_u64(seed);
    let mut gen_rng = Rng::seed_from_u64(seed ^ 0xd1f7_05ab_c93e_4410);
    env.reset(&mut env_rng);

    let mut feat_state = FeatureState::default();
    let mut segments: Vec<SegmentMeta> = Vec::new();
    let mut traces: Vec<SegmentTrace> = Vec::new();
    let mut total_nfe = 0.0;
    let mut total_wall = 0.0;

    while !env.done() {
        let obs = env.observe();
        let cond = den.encode(&obs)?;

        // Scheduler decision (runs "in parallel with the encoder" in the
        // paper; structurally it costs microseconds of pure Rust here).
        let params = match hook.as_deref_mut() {
            Some(h) => {
                let phase_frac = env.phase() as f32 / env.num_phases().max(1) as f32;
                let feat = features(&obs, env.progress(), phase_frac, &feat_state);
                let p = h.decide(&feat);
                generator.set_params(p);
                p
            }
            None => SpecParams::fixed_default(),
        };

        let mut trace = SegmentTrace::default();
        let segment = generator.generate(den, &cond, &mut gen_rng, &mut trace)?;

        // Execute the first EXEC_STEPS actions (receding horizon).
        let env_step = env.steps();
        let phase = env.phase();
        let mut speed_sum = 0.0f32;
        let mut executed = 0usize;
        for i in 0..EXEC_STEPS.min(HORIZON) {
            if env.done() {
                break;
            }
            env.step(&segment[i * ACT_DIM..(i + 1) * ACT_DIM]);
            speed_sum += env.ee_speed();
            executed += 1;
        }

        let meta = SegmentMeta {
            env_step,
            phase,
            ee_speed: if executed > 0 { speed_sum / executed as f32 } else { 0.0 },
            drafts: trace.drafts(),
            accepted: trace.accepted(),
            nfe: trace.nfe,
            wall_secs: trace.wall_secs,
            params,
        };
        total_nfe += trace.nfe;
        total_wall += trace.wall_secs;

        // Feature-state update for the next decision.
        feat_state.recent_acceptance = if meta.drafts > 0 {
            meta.accepted as f32 / meta.drafts as f32
        } else {
            1.0
        };
        feat_state.recent_drafts = meta.drafts as f32;
        feat_state.recent_speed = meta.ee_speed;
        feat_state.last_params = params;

        if let Some(h) = hook.as_deref_mut() {
            let outcome = SegmentOutcome {
                meta: &meta,
                done: env.done(),
                success: env.success(),
                score: env.score(),
                task: env.task(),
                t_max: env.max_steps(),
            };
            h.post_segment(&outcome);
        }
        segments.push(meta);
        traces.push(trace);
    }
    if let Some(h) = hook.as_deref_mut() {
        h.finish_episode();
    }

    Ok(EpisodeResult {
        task: env.task(),
        style,
        method: generator.method(),
        success: env.success(),
        score: env.score(),
        steps: env.steps(),
        nfe: total_nfe,
        wall_secs: total_wall,
        segments,
        traces,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::make_generator;
    use crate::envs::make_env;
    use crate::policy::mock::MockDenoiser;

    fn run(task: Task, method: Method, seed: u64) -> EpisodeResult {
        let den = MockDenoiser::with_bias(0.05);
        let mut env = make_env(task, DemoStyle::Ph);
        let mut generator = make_generator(method);
        run_episode(&den, env.as_mut(), generator.as_mut(), DemoStyle::Ph, seed, None)
            .unwrap()
    }

    #[test]
    fn episode_terminates_and_accounts_nfe() {
        let r = run(Task::Lift, Method::TsDp, 0);
        assert!(r.steps > 0 && r.steps <= 102);
        assert!(!r.segments.is_empty());
        assert!(r.nfe > 0.0);
        let sum: f64 = r.segments.iter().map(|s| s.nfe).sum();
        assert!((sum - r.nfe).abs() < 1e-9);
    }

    #[test]
    fn vanilla_nfe_is_100_per_segment() {
        let r = run(Task::Lift, Method::Vanilla, 1);
        assert!((r.nfe_percent() - 100.0).abs() < 1e-9);
        assert_eq!(r.drafts(), 0);
    }

    #[test]
    fn ts_dp_nfe_is_far_below_vanilla() {
        let r = run(Task::Lift, Method::TsDp, 2);
        assert!(r.nfe_percent() < 50.0, "{}", r.nfe_percent());
        assert!(r.acceptance_rate() > 0.5, "{}", r.acceptance_rate());
    }

    #[test]
    fn hook_is_invoked_per_segment() {
        struct CountHook {
            decides: usize,
            posts: usize,
        }
        impl DecisionHook for CountHook {
            fn decide(&mut self, feat: &[f32]) -> SpecParams {
                assert_eq!(feat.len(), crate::scheduler::features::FEAT_DIM);
                self.decides += 1;
                SpecParams::fixed_k(4)
            }
            fn post_segment(&mut self, outcome: &SegmentOutcome<'_>) {
                assert_eq!(outcome.meta.params, SpecParams::fixed_k(4));
                self.posts += 1;
            }
        }
        let den = MockDenoiser::with_bias(0.0);
        let mut env = make_env(Task::PushT, DemoStyle::Ph);
        let mut generator = make_generator(Method::TsDp);
        let mut hook = CountHook { decides: 0, posts: 0 };
        let r = run_episode(
            &den,
            env.as_mut(),
            generator.as_mut(),
            DemoStyle::Ph,
            3,
            Some(&mut hook),
        )
        .unwrap();
        assert_eq!(hook.decides, r.segments.len());
        assert_eq!(hook.posts, r.segments.len());
        // The hook's fixed_k(4) must actually reach the engine.
        for s in &r.segments {
            assert_eq!(s.params, SpecParams::fixed_k(4));
        }
    }

    #[test]
    fn frequency_and_latency_are_consistent() {
        let r = run(Task::Lift, Method::TsDp, 4);
        let hz = r.frequency_hz();
        let lat = r.latency_secs();
        assert!(hz > 0.0 && lat > 0.0);
        assert!((hz - EXEC_STEPS as f64 / lat).abs() < 1e-9);
    }
}
