//! Figure regeneration: CSV series matching the paper's figures.

use crate::baselines::{make_generator, TsDp};
use crate::config::{DemoStyle, Method, SpecParams, Task, DIFFUSION_STEPS};
use crate::envs::make_env;
use crate::harness::episode::run_episode;
use crate::policy::Denoiser;
use crate::scheduler::{SchedulerPolicy, ServingHook};
use crate::util::tensorio::write_csv;
use anyhow::Result;
use std::path::Path;

/// Fig. 3: acceptance probability vs denoising timestep.
/// (a) across draft-horizon settings, (b) across sigma scales — showing
/// the early/late collapse and the σ rescue.
pub fn figure3(den: &dyn Denoiser, out_dir: &Path, episodes: usize, seed: u64) -> Result<()> {
    let configs: Vec<(String, SpecParams)> = vec![
        ("k4_ss2".into(), SpecParams { stages: crate::config::StageParams::uniform(4), lambda: 0.05, sigma_scale: 2.0 }),
        ("k8_ss2".into(), SpecParams::fixed_k(8)),
        ("k16_ss2".into(), SpecParams { stages: crate::config::StageParams::uniform(16), lambda: 0.05, sigma_scale: 2.0 }),
        ("k8_ss1".into(), SpecParams { stages: crate::config::StageParams::uniform(8), lambda: 0.05, sigma_scale: 1.0 }),
        ("k8_ss4".into(), SpecParams { stages: crate::config::StageParams::uniform(8), lambda: 0.05, sigma_scale: 4.0 }),
    ];
    let mut header: Vec<&str> = vec!["t"];
    for (name, _) in &configs {
        header.push(name.as_str());
    }
    // Collect mean acceptance probability per timestep per config.
    let mut series: Vec<Vec<(f64, usize)>> = vec![vec![(0.0, 0); DIFFUSION_STEPS]; configs.len()];
    for (ci, (_, params)) in configs.iter().enumerate() {
        for ep in 0..episodes {
            let mut env = make_env(Task::Can, DemoStyle::Ph);
            let mut generator = TsDp::new(*params);
            let r = run_episode(
                den,
                env.as_mut(),
                &mut generator,
                DemoStyle::Ph,
                seed ^ (ep as u64 + 1),
                None,
            )?;
            for trace in &r.traces {
                for round in &trace.rounds {
                    for (j, p) in round.probs.iter().enumerate() {
                        let t = round.t_start - j;
                        series[ci][t].0 += p;
                        series[ci][t].1 += 1;
                    }
                }
            }
        }
    }
    let rows: Vec<Vec<f32>> = (0..DIFFUSION_STEPS)
        .map(|t| {
            let mut row = vec![t as f32];
            for s in &series {
                let (sum, n) = s[t];
                row.push(if n > 0 { (sum / n as f64) as f32 } else { f32::NAN });
            }
            row
        })
        .collect();
    write_csv(&out_dir.join("fig3_acceptance_vs_timestep.csv"), &header, &rows)
}

/// Fig. 4: accepted drafts vs end-effector velocity along one Can-PH
/// episode.
pub fn figure4(den: &dyn Denoiser, out_dir: &Path, seed: u64) -> Result<()> {
    let mut env = make_env(Task::Can, DemoStyle::Ph);
    // Discriminative acceptance settings (strict λ, unscaled σ): with the
    // serving defaults the distilled drafter is accepted near-uniformly,
    // which would flatten the velocity correlation this figure probes.
    let mut generator = TsDp::new(SpecParams {
        stages: crate::config::StageParams::uniform(8),
        lambda: 0.4,
        sigma_scale: 1.0,
    });
    let r = run_episode(den, env.as_mut(), &mut generator, DemoStyle::Ph, seed, None)?;
    let rows: Vec<Vec<f32>> = r
        .segments
        .iter()
        .map(|s| {
            vec![
                s.env_step as f32,
                s.accepted as f32,
                s.drafts as f32,
                s.ee_speed,
                s.phase as f32,
            ]
        })
        .collect();
    write_csv(
        &out_dir.join("fig4_velocity_vs_accepted.csv"),
        &["env_step", "accepted", "drafts", "ee_speed", "phase"],
        &rows,
    )
}

/// Fig. 5: temporal variation of the scheduled parameters over an
/// episode.
pub fn figure5(
    den: &dyn Denoiser,
    policy: &SchedulerPolicy,
    out_dir: &Path,
    seed: u64,
) -> Result<()> {
    let mut env = make_env(Task::Can, DemoStyle::Ph);
    let mut generator = TsDp::new(SpecParams::fixed_default());
    let mut hook = ServingHook::new(policy.clone());
    let r = run_episode(
        den,
        env.as_mut(),
        &mut generator,
        DemoStyle::Ph,
        seed,
        Some(&mut hook),
    )?;
    let rows: Vec<Vec<f32>> = r
        .segments
        .iter()
        .map(|s| {
            vec![
                s.env_step as f32,
                s.params.stages.k_early as f32,
                s.params.stages.k_mid as f32,
                s.params.stages.k_late as f32,
                s.params.lambda,
                s.params.sigma_scale,
                s.ee_speed,
            ]
        })
        .collect();
    write_csv(
        &out_dir.join("fig5_scheduled_params.csv"),
        &["env_step", "k_early", "k_mid", "k_late", "lambda", "sigma_scale", "ee_speed"],
        &rows,
    )
}

/// Fig. 6 / Supp. Fig. 1: acceptance rate and draft count, scheduled vs
/// fixed, per task.
pub fn figure6(
    den: &dyn Denoiser,
    policy: Option<&SchedulerPolicy>,
    out_dir: &Path,
    seed: u64,
) -> Result<()> {
    let tasks =
        [Task::Lift, Task::Can, Task::Square, Task::Transport, Task::ToolHang, Task::PushT];
    for task in tasks {
        let mut rows: Vec<Vec<f32>> = Vec::new();
        // Fixed-parameter run.
        let mut env = make_env(task, DemoStyle::Ph);
        let mut generator = make_generator(Method::TsDp);
        let fixed =
            run_episode(den, env.as_mut(), generator.as_mut(), DemoStyle::Ph, seed, None)?;
        // Scheduled run (same seed => same env layout).
        let scheduled = match policy {
            Some(p) => {
                let mut env = make_env(task, DemoStyle::Ph);
                let mut generator = TsDp::new(SpecParams::fixed_default());
                let mut hook = ServingHook::new(p.clone());
                Some(run_episode(
                    den,
                    env.as_mut(),
                    &mut generator,
                    DemoStyle::Ph,
                    seed,
                    Some(&mut hook),
                )?)
            }
            None => None,
        };
        let n = fixed
            .segments
            .len()
            .max(scheduled.as_ref().map(|s| s.segments.len()).unwrap_or(0));
        for i in 0..n {
            let f = fixed.segments.get(i);
            let s = scheduled.as_ref().and_then(|r| r.segments.get(i));
            let rate = |m: Option<&crate::harness::episode::SegmentMeta>| -> f32 {
                m.map(|m| {
                    if m.drafts > 0 {
                        m.accepted as f32 / m.drafts as f32
                    } else {
                        f32::NAN
                    }
                })
                .unwrap_or(f32::NAN)
            };
            rows.push(vec![
                i as f32,
                rate(f),
                f.map(|m| m.drafts as f32).unwrap_or(f32::NAN),
                rate(s),
                s.map(|m| m.drafts as f32).unwrap_or(f32::NAN),
            ]);
        }
        write_csv(
            &out_dir.join(format!("fig6_{}.csv", task.name())),
            &["segment", "fixed_accept_rate", "fixed_drafts", "sched_accept_rate", "sched_drafts"],
            &rows,
        )?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::mock::MockDenoiser;
    use crate::util::testing::TempDir;

    #[test]
    fn figures_3_and_4_write_csvs() {
        let den = MockDenoiser::with_bias_fn(|t| if t > 80 || t < 20 { 0.3 } else { 0.05 });
        let dir = TempDir::new("figs");
        figure3(&den, dir.path(), 1, 0).unwrap();
        figure4(&den, dir.path(), 0).unwrap();
        let f3 = std::fs::read_to_string(dir.path().join("fig3_acceptance_vs_timestep.csv"))
            .unwrap();
        assert!(f3.lines().count() == DIFFUSION_STEPS + 1);
        let f4 =
            std::fs::read_to_string(dir.path().join("fig4_velocity_vs_accepted.csv")).unwrap();
        assert!(f4.lines().count() > 2);
    }

    #[test]
    fn figures_5_and_6_write_csvs() {
        let den = MockDenoiser::with_bias(0.1);
        let dir = TempDir::new("figs56");
        let mut rng = crate::util::Rng::seed_from_u64(0);
        let policy = SchedulerPolicy::init(&mut rng);
        figure5(&den, &policy, dir.path(), 1).unwrap();
        figure6(&den, Some(&policy), dir.path(), 1).unwrap();
        assert!(dir.path().join("fig5_scheduled_params.csv").exists());
        assert!(dir.path().join("fig6_lift.csv").exists());
        assert!(dir.path().join("fig6_push_t.csv").exists());
    }
}
