//! Chrome trace-event JSON export for recorded spans.
//!
//! The output loads directly in Perfetto (<https://ui.perfetto.dev>) or
//! `chrome://tracing`: one process (`pid 0`, the serving fleet), one
//! named thread row per lane (shard workers, per-shard queue lanes,
//! session drivers, the learner). Thread-sequential stages export as
//! `B`/`E` duration pairs produced by a stack sweep, so per-lane events
//! are balanced and properly nested by construction; queue-wait
//! intervals — which legitimately overlap while many requests sit
//! buffered — export as self-contained complete (`X`) events on their
//! own lane. Timestamps are microseconds since the run's shared epoch.
//!
//! The file header (`otherData`) carries build/run [`Provenance`], so a
//! trace is self-describing: which crate version, kernel path, drafter
//! dtype, shard count, and workload mix produced it.

use crate::coordinator::workload::SessionSpec;
use crate::obs::span::{lane_name, SpanEvent, NO_ATTR};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Build/run provenance stamped into exported artifacts (the trace
/// header and `BENCH_*.json` metadata).
#[derive(Debug, Clone)]
pub struct Provenance {
    /// Crate version (`CARGO_PKG_VERSION`).
    pub crate_version: String,
    /// Active compute-kernel path (`scalar` / `lanes`).
    pub kernel_path: String,
    /// Drafter weight dtype / identity label (`base`, `f32`, `int8`, …).
    pub drafter: String,
    /// Shard workers in the fleet.
    pub shards: usize,
    /// Workload mix descriptor (`lift:ts_dp*4,push_t:vanilla`, …).
    pub workload: String,
}

impl Provenance {
    /// Provenance for the current build: crate version and kernel path
    /// are read from the environment; the run shape is passed in.
    pub fn collect(shards: usize, drafter: impl Into<String>, workload: impl Into<String>) -> Self {
        Self {
            crate_version: env!("CARGO_PKG_VERSION").to_string(),
            kernel_path: crate::kernels::Kernels::global().path().name().to_string(),
            drafter: drafter.into(),
            shards,
            workload: workload.into(),
        }
    }

    /// JSON object form (stable keys).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("crate_version", Json::Str(self.crate_version.clone())),
            ("kernel_path", Json::Str(self.kernel_path.clone())),
            ("drafter", Json::Str(self.drafter.clone())),
            ("shards", Json::Num(self.shards as f64)),
            ("workload", Json::Str(self.workload.clone())),
        ])
    }
}

/// Compact mix descriptor for a spec list: consecutive identical
/// `task:method` runs collapse to `task:method*n`, mirroring the
/// `--mix` grammar the CLI accepts.
pub fn describe_workload(specs: &[SessionSpec]) -> String {
    let mut parts: Vec<(String, usize)> = Vec::new();
    for spec in specs {
        let key = format!("{}:{}", spec.task.name(), spec.method.name());
        match parts.last_mut() {
            Some((k, n)) if *k == key => *n += 1,
            _ => parts.push((key, 1)),
        }
    }
    parts
        .into_iter()
        .map(|(k, n)| if n == 1 { k } else { format!("{k}*{n}") })
        .collect::<Vec<_>>()
        .join(",")
}

/// Render recorded spans as a Chrome trace-event JSON document.
pub fn chrome_trace(events: &[SpanEvent], prov: &Provenance) -> Json {
    let mut out: Vec<Json> = Vec::new();
    out.push(meta_event(0, "process_name", "ts-dp serving fleet"));
    // One named row per lane, sorted so shards render above sessions.
    let mut lanes: Vec<u32> = events.iter().map(|e| e.lane).collect();
    lanes.sort_unstable();
    lanes.dedup();
    for &lane in &lanes {
        out.push(meta_event(lane, "thread_name", &lane_name(lane)));
    }
    for &lane in &lanes {
        let mut nest: Vec<&SpanEvent> = Vec::new();
        let mut flat: Vec<&SpanEvent> = Vec::new();
        for ev in events.iter().filter(|e| e.lane == lane) {
            if ev.kind.overlaps() {
                flat.push(ev);
            } else {
                nest.push(ev);
            }
        }
        // Overlapping kinds: self-contained complete events.
        flat.sort_by_key(|e| (e.start_us, e.end_us));
        for ev in flat {
            let mut obj = event_common(ev, "X");
            obj.insert("dur".to_string(), Json::Num((ev.end_us - ev.start_us) as f64));
            out.push(Json::Obj(obj));
        }
        // Thread-sequential kinds: balanced, nested B/E pairs via a
        // stack sweep over (start asc, end desc)-ordered intervals.
        nest.sort_by_key(|e| (e.start_us, std::cmp::Reverse(e.end_us)));
        let mut stack: Vec<(u64, Json)> = Vec::new();
        for ev in nest {
            while let Some(&(top_end, _)) = stack.last() {
                if top_end <= ev.start_us {
                    let (end, e_ev) = stack.pop().expect("stack non-empty");
                    out.push(end_event(end, &e_ev));
                } else {
                    break;
                }
            }
            // Defensive laminarity: a child may not outlive its parent
            // (the recorder's sequential call sites never produce this,
            // but a clamped trace is always well-formed).
            let end = match stack.last() {
                Some(&(top_end, _)) => ev.end_us.min(top_end),
                None => ev.end_us,
            };
            let obj = Json::Obj(event_common(ev, "B"));
            out.push(obj.clone());
            stack.push((end, obj));
        }
        while let Some((end, e_ev)) = stack.pop() {
            out.push(end_event(end, &e_ev));
        }
    }
    Json::obj(vec![
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        ("otherData", prov.to_json()),
    ])
}

/// Write the trace to `path` (pretty-printed, parent dirs created).
pub fn write_chrome_trace(path: &Path, events: &[SpanEvent], prov: &Provenance) -> Result<()> {
    chrome_trace(events, prov)
        .save(path)
        .with_context(|| format!("writing Chrome trace to {}", path.display()))
}

fn meta_event(tid: u32, name: &str, value: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Num(0.0)),
        ("tid", Json::Num(tid as f64)),
        ("ts", Json::Num(0.0)),
        ("name", Json::Str(name.to_string())),
        ("args", Json::obj(vec![("name", Json::Str(value.to_string()))])),
    ])
}

/// Shared fields of a B/X event for `ev`.
fn event_common(ev: &SpanEvent, ph: &str) -> BTreeMap<String, Json> {
    let mut args: Vec<(&str, Json)> = Vec::new();
    for (key, val) in [
        ("session", ev.attrs.session),
        ("segment", ev.attrs.segment),
        ("round", ev.attrs.round),
        ("policy_epoch", ev.attrs.policy_epoch),
        ("count", ev.attrs.count),
    ] {
        if val != NO_ATTR {
            args.push((key, Json::Num(val as f64)));
        }
    }
    let mut obj = BTreeMap::new();
    obj.insert("ph".to_string(), Json::Str(ph.to_string()));
    obj.insert("pid".to_string(), Json::Num(0.0));
    obj.insert("tid".to_string(), Json::Num(ev.lane as f64));
    obj.insert("ts".to_string(), Json::Num(ev.start_us as f64));
    obj.insert("name".to_string(), Json::Str(ev.kind.name().to_string()));
    obj.insert("cat".to_string(), Json::Str("serving".to_string()));
    if !args.is_empty() {
        obj.insert("args".to_string(), Json::obj(args));
    }
    obj
}

/// The `E` event closing a `B` event, at timestamp `end`.
fn end_event(end: u64, b_ev: &Json) -> Json {
    let tid = b_ev.get("tid").expect("B event has tid").clone();
    let name = b_ev.get("name").expect("B event has name").clone();
    Json::Obj(BTreeMap::from([
        ("ph".to_string(), Json::Str("E".to_string())),
        ("pid".to_string(), Json::Num(0.0)),
        ("tid".to_string(), tid),
        ("ts".to_string(), Json::Num(end as f64)),
        ("name".to_string(), name),
        ("cat".to_string(), Json::Str("serving".to_string())),
    ]))
}

/// Structural summary returned by [`validate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceStats {
    /// Duration (`B`/`E`) span pairs.
    pub spans: usize,
    /// Complete (`X`) events.
    pub complete: usize,
    /// Distinct lanes carrying events.
    pub lanes: usize,
}

/// Validate a Chrome trace document's structure: every event carries
/// `ph`/`pid`/`tid`/`ts`/`name`; per lane, timestamps are monotone
/// non-decreasing (metadata events exempt) and `B`/`E` pairs are
/// balanced and properly nested. Shared by the unit/integration tests
/// and mirrored by `scripts/check_trace.py` for CI smoke runs.
pub fn validate(doc: &Json) -> Result<TraceStats> {
    let events = doc.get("traceEvents")?.as_arr()?;
    let mut last_ts: BTreeMap<u64, f64> = BTreeMap::new();
    let mut stacks: BTreeMap<u64, Vec<String>> = BTreeMap::new();
    let mut spans = 0usize;
    let mut complete = 0usize;
    for ev in events {
        let ph = ev.get("ph")?.as_str()?.to_string();
        ev.get("pid")?.as_f64()?;
        let tid = ev.get("tid")?.as_usize()? as u64;
        let ts = ev.get("ts")?.as_f64()?;
        let name = ev.get("name")?.as_str()?.to_string();
        if ph == "M" {
            continue;
        }
        let prev = last_ts.entry(tid).or_insert(f64::NEG_INFINITY);
        if ts < *prev {
            bail!("lane {tid}: ts {ts} before {prev} ({name})");
        }
        *prev = ts;
        match ph.as_str() {
            "B" => stacks.entry(tid).or_default().push(name),
            "E" => {
                let open = stacks.entry(tid).or_default().pop();
                match open {
                    Some(top) if top == name => spans += 1,
                    Some(top) => bail!("lane {tid}: E {name} closes B {top}"),
                    None => bail!("lane {tid}: E {name} without open B"),
                }
            }
            "X" => {
                if ev.get("dur")?.as_f64()? < 0.0 {
                    bail!("lane {tid}: negative dur on {name}");
                }
                complete += 1;
            }
            other => bail!("lane {tid}: unsupported ph {other:?}"),
        }
    }
    for (tid, stack) in &stacks {
        if !stack.is_empty() {
            bail!("lane {tid}: {} unclosed B event(s)", stack.len());
        }
    }
    Ok(TraceStats { spans, complete, lanes: last_ts.len() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Method, Task};
    use crate::obs::span::{queue_lane, Attrs, SpanKind, SpanRecorder};
    use std::time::{Duration, Instant};

    fn prov() -> Provenance {
        Provenance {
            crate_version: "0.0.0-test".to_string(),
            kernel_path: "scalar".to_string(),
            drafter: "base".to_string(),
            shards: 1,
            workload: "lift:ts_dp".to_string(),
        }
    }

    /// Record at explicit offsets from a fixed epoch.
    fn rec_at(rec: &mut SpanRecorder, epoch: Instant, kind: SpanKind, s: u64, e: u64, a: Attrs) {
        rec.record_between(
            kind,
            epoch + Duration::from_micros(s),
            epoch + Duration::from_micros(e),
            a,
        );
    }

    #[test]
    fn nesting_round_trips_through_export() {
        let epoch = Instant::now();
        let mut rec = SpanRecorder::new(epoch, 0, 64, true);
        // draft_wave [10, 90] enclosing gemv [20, 80]; then verify.
        rec_at(&mut rec, epoch, SpanKind::Gemv, 20, 80, Attrs { count: 3, ..Attrs::NONE });
        rec_at(&mut rec, epoch, SpanKind::DraftWave, 10, 90, Attrs::NONE);
        rec_at(&mut rec, epoch, SpanKind::VerifyCall, 100, 140, Attrs { count: 2, ..Attrs::NONE });
        let doc = chrome_trace(&rec.events(), &prov());
        let stats = validate(&doc).expect("exported trace validates");
        assert_eq!(stats.spans, 3);
        assert_eq!(stats.complete, 0);
        // The B/E sequence reconstructs the nesting: wave opens before
        // gemv, gemv closes before the wave does.
        let names: Vec<(String, String)> = doc
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("ph").unwrap().as_str().unwrap() != "M")
            .map(|e| {
                (
                    e.get("ph").unwrap().as_str().unwrap().to_string(),
                    e.get("name").unwrap().as_str().unwrap().to_string(),
                )
            })
            .collect();
        let expect: Vec<(String, String)> = [
            ("B", "draft_wave"),
            ("B", "gemv"),
            ("E", "gemv"),
            ("E", "draft_wave"),
            ("B", "verify"),
            ("E", "verify"),
        ]
        .iter()
        .map(|(p, n)| (p.to_string(), n.to_string()))
        .collect();
        assert_eq!(names, expect);
    }

    #[test]
    fn overlapping_queue_waits_export_as_complete_events() {
        let epoch = Instant::now();
        let mut rec = SpanRecorder::new(epoch, 0, 64, true);
        let lane = Attrs { lane: queue_lane(0), session: 1, ..Attrs::NONE };
        rec_at(&mut rec, epoch, SpanKind::QueueWait, 0, 50, lane);
        rec_at(&mut rec, epoch, SpanKind::QueueWait, 10, 70, lane); // overlaps
        let doc = chrome_trace(&rec.events(), &prov());
        let stats = validate(&doc).expect("overlap exports validly");
        assert_eq!(stats.complete, 2);
        assert_eq!(stats.spans, 0);
    }

    #[test]
    fn header_carries_provenance_and_args_round_trip() {
        let epoch = Instant::now();
        let mut rec = SpanRecorder::new(epoch, 2, 64, true);
        let attrs = Attrs { session: 7, segment: 3, round: 1, policy_epoch: 4, ..Attrs::NONE };
        rec_at(&mut rec, epoch, SpanKind::Admission, 5, 9, attrs);
        let doc = chrome_trace(&rec.events(), &prov());
        let other = doc.get("otherData").unwrap();
        assert_eq!(other.get("kernel_path").unwrap().as_str().unwrap(), "scalar");
        assert_eq!(other.get("shards").unwrap().as_usize().unwrap(), 1);
        assert_eq!(other.get("crate_version").unwrap().as_str().unwrap(), "0.0.0-test");
        // Round-trip through the serializer: still valid, args intact.
        let parsed = Json::parse(&format!("{doc:#}")).expect("serialized trace parses");
        validate(&parsed).expect("parsed trace validates");
        let b = parsed
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .find(|e| e.get("ph").unwrap().as_str().unwrap() == "B")
            .expect("B event present");
        let args = b.get("args").unwrap();
        assert_eq!(args.get("session").unwrap().as_usize().unwrap(), 7);
        assert_eq!(args.get("segment").unwrap().as_usize().unwrap(), 3);
        assert_eq!(args.get("policy_epoch").unwrap().as_usize().unwrap(), 4);
    }

    #[test]
    fn validate_rejects_malformed_traces() {
        // Unbalanced: B without E.
        let doc = Json::obj(vec![(
            "traceEvents",
            Json::Arr(vec![Json::obj(vec![
                ("ph", Json::Str("B".into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(0.0)),
                ("ts", Json::Num(1.0)),
                ("name", Json::Str("x".into())),
            ])]),
        )]);
        assert!(validate(&doc).is_err());
        // Non-monotone ts on one lane.
        let mk = |ph: &str, ts: f64| {
            Json::obj(vec![
                ("ph", Json::Str(ph.into())),
                ("pid", Json::Num(0.0)),
                ("tid", Json::Num(0.0)),
                ("ts", Json::Num(ts)),
                ("name", Json::Str("x".into())),
            ])
        };
        let doc = Json::obj(vec![("traceEvents", Json::Arr(vec![mk("B", 5.0), mk("E", 2.0)]))]);
        assert!(validate(&doc).is_err());
    }

    #[test]
    fn workload_descriptor_collapses_runs() {
        let specs = vec![
            SessionSpec::new(Task::Lift, Method::TsDp),
            SessionSpec::new(Task::Lift, Method::TsDp),
            SessionSpec::new(Task::PushT, Method::Vanilla),
        ];
        assert_eq!(describe_workload(&specs), "lift:ts_dp*2,push_t:vanilla");
        assert_eq!(describe_workload(&[]), "");
    }
}
