//! Flight recorder: periodic gauge snapshots of a live serving fleet.
//!
//! Each shard worker owns one [`FlightRecorder`] when `--obs-interval`
//! is set; once per interval the shard loop snapshots its live gauges
//! ([`FlightGauges`]) into an in-memory time series. At shutdown the
//! coordinator merges every shard's samples into one JSONL file (one
//! compact JSON object per line, timestamp-ordered) plus a
//! Prometheus-style text exposition of the final sample per shard —
//! the first time-resolved view of queue depth, pressure, occupancy,
//! accept rate, and shedding, and the signal bus a future autoscaler
//! (ROADMAP Open item 4) consumes.
//!
//! Like span tracing, sampling is read-only: gauges are copied, never
//! branched on, so serving bits are identical with the recorder on or
//! off.

use crate::coordinator::qos::QosClass;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::io::Write;
use std::path::Path;
use std::time::{Duration, Instant};

/// Number of QoS classes (one queue-depth gauge each).
pub const N_CLASSES: usize = QosClass::ALL.len();

/// EWMA smoothing factor for the accept-rate gauge.
const ACCEPT_EWMA_ALPHA: f64 = 0.2;

/// Live gauges a shard exposes to the sampler (copied, never mutated).
#[derive(Debug, Clone, Copy, Default)]
pub struct FlightGauges {
    /// Buffered requests across the shard's batcher queues.
    pub queue_depth: usize,
    /// Buffered requests per QoS class (`QosClass::ALL` order).
    pub queue_by_class: [usize; N_CLASSES],
    /// Jobs currently resident in the shard's job table.
    pub inflight: usize,
    /// Estimated seconds of backlog (QoS pressure gauge; 0 without QoS).
    pub pressure_secs: f64,
    /// Size of the most recent fused draft wave.
    pub draft_wave_occ: usize,
    /// Size of the most recent fused verify call.
    pub verify_occ: usize,
    /// KV-arena blocks in use (high water so far; 0 for backends
    /// without an arena).
    pub arena_blocks: usize,
    /// Highest scheduler policy epoch seen on this shard.
    pub policy_epoch: u64,
    /// Requests served so far (cumulative counter).
    pub served: u64,
    /// Requests shed so far (cumulative counter; rates are first
    /// differences between samples).
    pub sheds: u64,
    /// Active shard workers in the fleet at snapshot time (constant on
    /// a fixed fleet; breathes between min and max under `--autoscale`).
    pub fleet_shards: usize,
}

/// One timestamped gauge snapshot.
#[derive(Debug, Clone, Copy)]
pub struct FlightSample {
    /// Microseconds since the run's shared epoch.
    pub t_us: u64,
    /// Shard the snapshot came from.
    pub shard: u32,
    /// Accept-rate EWMA over served TS-DP segments (NaN-free; 0 until
    /// the first observation).
    pub accept_ewma: f64,
    /// The gauges at snapshot time.
    pub gauges: FlightGauges,
}

impl FlightSample {
    /// JSON object form (one JSONL line).
    pub fn to_json(&self) -> Json {
        let g = &self.gauges;
        Json::obj(vec![
            ("t_us", Json::Num(self.t_us as f64)),
            ("shard", Json::Num(self.shard as f64)),
            ("queue_depth", Json::Num(g.queue_depth as f64)),
            ("queue_by_class", Json::usizes(g.queue_by_class)),
            ("inflight", Json::Num(g.inflight as f64)),
            ("pressure_secs", Json::Num(g.pressure_secs)),
            ("draft_wave_occ", Json::Num(g.draft_wave_occ as f64)),
            ("verify_occ", Json::Num(g.verify_occ as f64)),
            ("arena_blocks", Json::Num(g.arena_blocks as f64)),
            ("accept_ewma", Json::Num(self.accept_ewma)),
            ("policy_epoch", Json::Num(g.policy_epoch as f64)),
            ("served", Json::Num(g.served as f64)),
            ("sheds", Json::Num(g.sheds as f64)),
            ("fleet_shards", Json::Num(g.fleet_shards as f64)),
        ])
    }

    /// Parse one JSONL line's object back into a sample.
    pub fn from_json(j: &Json) -> Result<FlightSample> {
        let classes = j.get("queue_by_class")?.as_usize_vec()?;
        anyhow::ensure!(classes.len() == N_CLASSES, "expected {N_CLASSES} class depths");
        let mut queue_by_class = [0usize; N_CLASSES];
        queue_by_class.copy_from_slice(&classes);
        Ok(FlightSample {
            t_us: j.get("t_us")?.as_f64()? as u64,
            shard: j.get("shard")?.as_usize()? as u32,
            accept_ewma: j.get("accept_ewma")?.as_f64()?,
            gauges: FlightGauges {
                queue_depth: j.get("queue_depth")?.as_usize()?,
                queue_by_class,
                inflight: j.get("inflight")?.as_usize()?,
                pressure_secs: j.get("pressure_secs")?.as_f64()?,
                draft_wave_occ: j.get("draft_wave_occ")?.as_usize()?,
                verify_occ: j.get("verify_occ")?.as_usize()?,
                arena_blocks: j.get("arena_blocks")?.as_usize()?,
                policy_epoch: j.get("policy_epoch")?.as_f64()? as u64,
                served: j.get("served")?.as_f64()? as u64,
                sheds: j.get("sheds")?.as_f64()? as u64,
                // Absent in recordings from before the elastic fleet:
                // default to 0 rather than failing the whole parse.
                fleet_shards: match j.get("fleet_shards") {
                    Ok(v) => v.as_usize()?,
                    Err(_) => 0,
                },
            },
        })
    }
}

/// Per-shard periodic sampler (see module docs).
#[derive(Debug)]
pub struct FlightRecorder {
    epoch: Instant,
    shard: u32,
    interval: Duration,
    last: Instant,
    accept_ewma: f64,
    seen_accept: bool,
    samples: Vec<FlightSample>,
}

impl FlightRecorder {
    /// Sampler for `shard`, timestamping against the run's `epoch`. The
    /// first sample fires one `interval` after construction.
    pub fn new(epoch: Instant, shard: usize, interval: Duration) -> Self {
        Self {
            epoch,
            shard: shard as u32,
            interval: interval.max(Duration::from_micros(100)),
            last: Instant::now(),
            accept_ewma: 0.0,
            seen_accept: false,
            samples: Vec::new(),
        }
    }

    /// True when at least one interval elapsed since the last sample.
    pub fn due(&self) -> bool {
        self.last.elapsed() >= self.interval
    }

    /// Fold one served TS-DP segment into the accept-rate EWMA.
    pub fn observe_accept(&mut self, drafts: usize, accepted: usize) {
        if drafts == 0 {
            return;
        }
        let rate = accepted as f64 / drafts as f64;
        if self.seen_accept {
            self.accept_ewma += ACCEPT_EWMA_ALPHA * (rate - self.accept_ewma);
        } else {
            self.accept_ewma = rate;
            self.seen_accept = true;
        }
    }

    /// Take one snapshot and reset the interval clock.
    pub fn sample(&mut self, gauges: FlightGauges) {
        let now = Instant::now();
        let t_us = now.saturating_duration_since(self.epoch).as_micros() as u64;
        self.samples.push(FlightSample {
            t_us,
            shard: self.shard,
            accept_ewma: self.accept_ewma,
            gauges,
        });
        self.last = now;
    }

    /// Samples recorded so far.
    pub fn samples(&self) -> &[FlightSample] {
        &self.samples
    }

    /// Consume the recorder, yielding its samples.
    pub fn into_samples(self) -> Vec<FlightSample> {
        self.samples
    }
}

/// Write samples as JSONL, timestamp-ordered (parent dirs created).
pub fn write_jsonl(path: &Path, samples: &[FlightSample]) -> Result<()> {
    let mut sorted: Vec<&FlightSample> = samples.iter().collect();
    sorted.sort_by_key(|s| (s.t_us, s.shard));
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    let mut out = String::new();
    for s in sorted {
        out.push_str(&format!("{}\n", s.to_json()));
    }
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(out.as_bytes()).with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Parse a JSONL file written by [`write_jsonl`].
pub fn read_jsonl(path: &Path) -> Result<Vec<FlightSample>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let mut out = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line).map_err(|e| anyhow::anyhow!("line {}: {e}", i + 1))?;
        out.push(FlightSample::from_json(&j).with_context(|| format!("line {}", i + 1))?);
    }
    Ok(out)
}

/// Prometheus-style text exposition of the *final* sample per shard
/// (the shutdown state of every gauge, plus cumulative counters).
pub fn prometheus(samples: &[FlightSample]) -> String {
    use std::collections::BTreeMap;
    let mut last: BTreeMap<u32, &FlightSample> = BTreeMap::new();
    for s in samples {
        let e = last.entry(s.shard).or_insert(s);
        if s.t_us >= e.t_us {
            *e = s;
        }
    }
    let mut out = String::new();
    let mut gauge = |name: &str, help: &str, kind: &str, rows: &[(String, f64)]| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        for (labels, v) in rows {
            // Integer-valued gauges print without a trailing ".0".
            if v.fract() == 0.0 && v.abs() < 1e15 {
                out.push_str(&format!("{name}{{{labels}}} {}\n", *v as i64));
            } else {
                out.push_str(&format!("{name}{{{labels}}} {v}\n"));
            }
        }
    };
    let per_shard = |f: &dyn Fn(&FlightSample) -> f64| -> Vec<(String, f64)> {
        last.values().map(|s| (format!("shard=\"{}\"", s.shard), f(s))).collect()
    };
    gauge(
        "tsdp_queue_depth",
        "Buffered requests in the shard's batcher.",
        "gauge",
        &per_shard(&|s| s.gauges.queue_depth as f64),
    );
    let mut class_rows = Vec::new();
    for s in last.values() {
        for (i, class) in QosClass::ALL.iter().enumerate() {
            class_rows.push((
                format!("shard=\"{}\",class=\"{}\"", s.shard, class.name()),
                s.gauges.queue_by_class[i] as f64,
            ));
        }
    }
    gauge(
        "tsdp_queue_depth_class",
        "Buffered requests per QoS class.",
        "gauge",
        &class_rows,
    );
    gauge(
        "tsdp_inflight",
        "Jobs resident in the shard's job table.",
        "gauge",
        &per_shard(&|s| s.gauges.inflight as f64),
    );
    gauge(
        "tsdp_pressure_seconds",
        "Estimated seconds of shard backlog (QoS pressure gauge).",
        "gauge",
        &per_shard(&|s| s.gauges.pressure_secs),
    );
    gauge(
        "tsdp_draft_wave_occupancy",
        "Size of the most recent fused draft wave.",
        "gauge",
        &per_shard(&|s| s.gauges.draft_wave_occ as f64),
    );
    gauge(
        "tsdp_verify_occupancy",
        "Size of the most recent fused verify call.",
        "gauge",
        &per_shard(&|s| s.gauges.verify_occ as f64),
    );
    gauge(
        "tsdp_kv_arena_blocks",
        "KV-arena blocks in use (high water).",
        "gauge",
        &per_shard(&|s| s.gauges.arena_blocks as f64),
    );
    gauge(
        "tsdp_accept_rate_ewma",
        "EWMA accept rate over served TS-DP segments.",
        "gauge",
        &per_shard(&|s| s.accept_ewma),
    );
    gauge(
        "tsdp_policy_epoch",
        "Highest scheduler policy epoch seen.",
        "gauge",
        &per_shard(&|s| s.gauges.policy_epoch as f64),
    );
    gauge(
        "tsdp_requests_served_total",
        "Requests served (cumulative).",
        "counter",
        &per_shard(&|s| s.gauges.served as f64),
    );
    gauge(
        "tsdp_requests_shed_total",
        "Requests shed (cumulative).",
        "counter",
        &per_shard(&|s| s.gauges.sheds as f64),
    );
    gauge(
        "tsdp_fleet_shards",
        "Active shard workers in the fleet.",
        "gauge",
        &per_shard(&|s| s.gauges.fleet_shards as f64),
    );
    out
}

/// Write the Prometheus exposition to `path`.
pub fn write_prometheus(path: &Path, samples: &[FlightSample]) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {}", dir.display()))?;
        }
    }
    std::fs::write(path, prometheus(samples))
        .with_context(|| format!("writing {}", path.display()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(t_us: u64, shard: u32) -> FlightSample {
        FlightSample {
            t_us,
            shard,
            accept_ewma: 0.9375,
            gauges: FlightGauges {
                queue_depth: 4,
                queue_by_class: [1, 2, 1],
                inflight: 3,
                pressure_secs: 0.125,
                draft_wave_occ: 3,
                verify_occ: 2,
                arena_blocks: 5,
                policy_epoch: 2,
                served: 40,
                sheds: 1,
                fleet_shards: 2,
            },
        }
    }

    #[test]
    fn jsonl_round_trips() {
        let dir = std::env::temp_dir().join(format!(
            "tsdp_obs_flight_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let path = dir.join("flight.jsonl");
        let samples = vec![sample(2_000, 1), sample(1_000, 0), sample(3_000, 0)];
        write_jsonl(&path, &samples).expect("write");
        let back = read_jsonl(&path).expect("parse back");
        assert_eq!(back.len(), 3);
        // Timestamp-ordered on disk.
        let ts: Vec<u64> = back.iter().map(|s| s.t_us).collect();
        assert_eq!(ts, vec![1_000, 2_000, 3_000]);
        assert_eq!(back[0].shard, 0);
        assert_eq!(back[0].gauges.queue_by_class, [1, 2, 1]);
        assert!((back[0].accept_ewma - 0.9375).abs() < 1e-12);
        assert_eq!(back[0].gauges.served, 40);
        assert_eq!(back[0].gauges.fleet_shards, 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn recorder_samples_and_ewma() {
        let epoch = Instant::now();
        let mut rec = FlightRecorder::new(epoch, 2, Duration::from_micros(100));
        assert!(!rec.due(), "first interval has not elapsed yet");
        rec.observe_accept(8, 8);
        rec.observe_accept(8, 4); // EWMA moves toward 0.5
        rec.observe_accept(0, 0); // no drafts: ignored
        let ewma = 1.0 + ACCEPT_EWMA_ALPHA * (0.5 - 1.0);
        rec.sample(FlightGauges { queue_depth: 1, ..FlightGauges::default() });
        std::thread::sleep(Duration::from_millis(1));
        assert!(rec.due());
        rec.sample(FlightGauges::default());
        let samples = rec.into_samples();
        assert_eq!(samples.len(), 2);
        assert!(samples[1].t_us >= samples[0].t_us);
        assert_eq!(samples[0].shard, 2);
        assert_eq!(samples[0].gauges.queue_depth, 1);
        assert!((samples[0].accept_ewma - ewma).abs() < 1e-12);
    }

    #[test]
    fn prometheus_exposes_last_sample_per_shard() {
        let mut s_late = sample(5_000, 0);
        s_late.gauges.queue_depth = 9;
        let text = prometheus(&[sample(1_000, 0), s_late, sample(2_000, 1)]);
        assert!(text.contains("# TYPE tsdp_queue_depth gauge"));
        assert!(text.contains("tsdp_queue_depth{shard=\"0\"} 9"), "last sample wins:\n{text}");
        assert!(text.contains("tsdp_queue_depth{shard=\"1\"} 4"));
        assert!(text.contains("tsdp_queue_depth_class{shard=\"0\",class=\"rt\"} 1"));
        assert!(text.contains("tsdp_requests_served_total{shard=\"0\"} 40"));
        assert!(text.contains("tsdp_accept_rate_ewma{shard=\"0\"} 0.9375"));
    }
}
