//! Observability for the serving fleet: span tracing + flight recorder.
//!
//! Dependency-free (std + the crate's own hand-rolled JSON), two layers:
//!
//! * **Span tracing** ([`span`], [`trace`]) — per-shard ring-buffered
//!   recorders capture the full segment lifecycle (queue wait,
//!   admission, draft wave, batched GEMV, fused verify, commit,
//!   finalize, scheduler decision, learner epoch) as nested spans with
//!   shard/session/segment/round/policy-epoch attributes, exported at
//!   run end as Chrome trace-event JSON (`serve --trace-out trace.json`,
//!   loadable in Perfetto or `chrome://tracing`). Per-stage wall-time
//!   attribution (p50/p95/p99 via [`crate::util::stats::Reservoir`])
//!   merges fleet-wide into `ServerMetrics::summary()` and the bench
//!   JSON.
//! * **Flight recorder** ([`flight`]) — a periodic sampler
//!   (`--obs-interval MS`, off by default) snapshots live gauges
//!   (per-class queue depth, pressure, wave occupancy, KV-arena blocks,
//!   accept-rate EWMA, policy epoch, shed counters) into a JSONL time
//!   series plus a Prometheus-style text exposition at shutdown.
//!
//! **Contract: observability never changes serving behavior.** Clocks
//! are read, never branched on; with everything off (the default) the
//! hot path performs no extra clock reads and no allocations, and the
//! golden serve trace is bit-identical whether tracing is on, off, or
//! absent (pinned by `tests/obs_trace.rs`; recorder overhead is gated
//! by the `serve_obs` bench section).

pub mod flight;
pub mod span;
pub mod trace;

pub use flight::{FlightGauges, FlightRecorder, FlightSample};
pub use span::{Attrs, SpanEvent, SpanKind, SpanRecorder, SpanSink, StageDist};
pub use trace::{describe_workload, Provenance};

use std::path::PathBuf;
use std::time::Duration;

/// Observability configuration for one serving run. Everything is off
/// by default; `ServeOptions` embeds this with `Default`, so existing
/// construction sites are untouched.
#[derive(Debug, Clone, Default)]
pub struct ObsConfig {
    /// Write a Chrome trace-event JSON file here at run end (None =
    /// span tracing disabled: zero-overhead no-op recorders).
    pub trace_out: Option<PathBuf>,
    /// Flight-recorder sampling interval (None = flight recorder off).
    pub obs_interval: Option<Duration>,
    /// Flight-recorder JSONL output path (defaults to `flight.jsonl`;
    /// the Prometheus exposition lands next to it with a `.prom`
    /// extension).
    pub obs_out: Option<PathBuf>,
    /// Span-ring capacity override per recorder (0 = default).
    pub ring_cap: usize,
}

impl ObsConfig {
    /// True when span tracing is active.
    pub fn tracing(&self) -> bool {
        self.trace_out.is_some()
    }

    /// True when the flight recorder is active.
    pub fn flight(&self) -> bool {
        self.obs_interval.is_some()
    }

    /// True when any observability output is requested.
    pub fn any(&self) -> bool {
        self.tracing() || self.flight()
    }

    /// Effective per-recorder ring capacity.
    pub fn effective_ring_cap(&self) -> usize {
        if self.ring_cap == 0 {
            span::DEFAULT_RING_CAP
        } else {
            self.ring_cap
        }
    }

    /// Flight-recorder JSONL path (the configured one or the default).
    pub fn flight_path(&self) -> PathBuf {
        self.obs_out.clone().unwrap_or_else(|| PathBuf::from("flight.jsonl"))
    }

    /// Prometheus exposition path derived from the JSONL path.
    pub fn prom_path(&self) -> PathBuf {
        self.flight_path().with_extension("prom")
    }
}

/// What the observability layer produced during one serving run
/// (attached to `ServeReport` when any output was requested).
#[derive(Debug, Clone, Default)]
pub struct ObsReport {
    /// Span events exported to the trace file.
    pub spans: usize,
    /// Span events overwritten by ring overflow (fleet total).
    pub spans_dropped: u64,
    /// Flight samples written.
    pub flight_samples: usize,
    /// Where the Chrome trace landed, if tracing was on.
    pub trace_path: Option<PathBuf>,
    /// Where the flight JSONL landed, if the recorder was on.
    pub flight_path: Option<PathBuf>,
    /// Where the Prometheus exposition landed, if the recorder was on.
    pub prom_path: Option<PathBuf>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_fully_off() {
        let cfg = ObsConfig::default();
        assert!(!cfg.tracing());
        assert!(!cfg.flight());
        assert!(!cfg.any());
        assert_eq!(cfg.effective_ring_cap(), span::DEFAULT_RING_CAP);
    }

    #[test]
    fn paths_derive_from_obs_out() {
        let cfg = ObsConfig {
            obs_interval: Some(Duration::from_millis(5)),
            obs_out: Some(PathBuf::from("/tmp/run1/fleet.jsonl")),
            ..ObsConfig::default()
        };
        assert!(cfg.flight() && cfg.any() && !cfg.tracing());
        assert_eq!(cfg.flight_path(), PathBuf::from("/tmp/run1/fleet.jsonl"));
        assert_eq!(cfg.prom_path(), PathBuf::from("/tmp/run1/fleet.prom"));
        let bare = ObsConfig { obs_interval: Some(Duration::from_millis(5)), ..Default::default() };
        assert_eq!(bare.flight_path(), PathBuf::from("flight.jsonl"));
        assert_eq!(bare.prom_path(), PathBuf::from("flight.prom"));
    }
}
