//! Ring-buffered span recorder for the serving fleet.
//!
//! Each shard worker owns one [`SpanRecorder`]; session drivers and the
//! background learner share one [`SpanSink`] (a mutex-wrapped recorder —
//! their event rates are per-segment and per-epoch, so contention is
//! negligible). Recording is bounded: a fixed-capacity ring overwrites
//! the oldest event under overflow (counted in `dropped`), while the
//! per-stage wall-time attribution ([`StageDist`]) keeps folding every
//! observation in regardless, so attribution stays exact over the whole
//! run even when the ring wraps.
//!
//! The recorder is behaviorally inert by contract: timestamps are read
//! from a shared monotonic epoch ([`std::time::Instant`]) and *never*
//! branched on by serving logic, and when disabled every method is an
//! early-return that touches no clock and allocates nothing — the golden
//! trace is bit-identical with tracing on, off, or absent.

use crate::util::stats::{OnlineStats, Reservoir};
use std::sync::Mutex;
use std::time::Instant;

/// Default ring capacity per recorder (fixed memory bound; one event is
/// a few dozen bytes, so the default is ~2 MB per shard at worst).
pub const DEFAULT_RING_CAP: usize = 65_536;

/// Reservoir capacity backing each stage's percentile estimate.
const STAGE_RESERVOIR_CAP: usize = 4096;

/// Attribute value meaning "not applicable" on a [`SpanEvent`].
pub const NO_ATTR: u32 = u32::MAX;

/// Lane (exported as the Chrome-trace `tid`) of shard worker `shard`.
pub fn shard_lane(shard: usize) -> u32 {
    shard as u32
}

/// Lane carrying shard `shard`'s queue-wait intervals. Queue waits of
/// concurrently buffered requests overlap, so they live on their own
/// lane and export as complete (`ph:"X"`) events rather than B/E pairs.
pub fn queue_lane(shard: usize) -> u32 {
    1_000 + shard as u32
}

/// Lane of session driver `session`.
pub fn session_lane(session: usize) -> u32 {
    2_000 + session as u32
}

/// Lane of the background PPO learner thread.
pub const LEARNER_LANE: u32 = 60_000;

/// Lane of the elastic-fleet dispatcher (scale decisions + migrations).
pub const FLEET_LANE: u32 = 61_000;

/// First lane of the HTTP frontend's connection handlers.
pub const HTTP_LANE_BASE: u32 = 50_000;

/// Lane of HTTP connection handler `conn` (connections are numbered in
/// accept order by the frontend).
pub fn http_lane(conn: usize) -> u32 {
    HTTP_LANE_BASE + (conn as u32 % (LEARNER_LANE - HTTP_LANE_BASE))
}

/// Human-readable lane name for trace thread metadata.
pub fn lane_name(lane: u32) -> String {
    match lane {
        LEARNER_LANE => "learner".to_string(),
        FLEET_LANE => "fleet".to_string(),
        l if l < 1_000 => format!("shard {l}"),
        l if l < 2_000 => format!("shard {} queue", l - 1_000),
        l if (HTTP_LANE_BASE..LEARNER_LANE).contains(&l) => {
            format!("http conn {}", l - HTTP_LANE_BASE)
        }
        l => format!("session {}", l - 2_000),
    }
}

/// Instrumented stages of the segment lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpanKind {
    /// Request submission → shard admission (time spent queued).
    QueueWait,
    /// Admission work on the shard: deadline checks, observation
    /// encode, job start (or the whole blocking baseline generation).
    Admission,
    /// One draft-wave phase: per-job noise draws, the fused rollout,
    /// and result distribution (encloses [`SpanKind::Gemv`]).
    DraftWave,
    /// The fused `drafter_rollout_many` call itself — the batched GEMV
    /// advancing every in-flight draft one denoising step per wave.
    Gemv,
    /// The fused multi-request `target_verify_many` call plus the
    /// per-job accept scans it feeds.
    VerifyCall,
    /// The accept/commit scan distributing verify output to jobs.
    Commit,
    /// ODE finalization + reply of a job whose plan fully committed.
    Finalize,
    /// Scheduler policy inference on the session thread.
    SchedulerDecision,
    /// One PPO epoch on the background learner thread.
    LearnerEpoch,
    /// HTTP request parse on a frontend connection handler (read +
    /// validate the request line, headers, and body).
    HttpParse,
    /// HTTP response write on a frontend connection handler (headers
    /// through final byte — for streamed segments this spans every
    /// flushed chunk, so wire overhead shows up in stage attribution).
    HttpWrite,
    /// One deterministic session migration on the elastic fleet:
    /// snapshot request → snapshot received → installed on the target
    /// shard (`attrs.session` = moved session, `attrs.count` = target
    /// shard, recorded on [`FLEET_LANE`]).
    Migration,
}

impl SpanKind {
    /// Every kind, export order.
    pub const ALL: [SpanKind; 12] = [
        SpanKind::QueueWait,
        SpanKind::Admission,
        SpanKind::DraftWave,
        SpanKind::Gemv,
        SpanKind::VerifyCall,
        SpanKind::Commit,
        SpanKind::Finalize,
        SpanKind::SchedulerDecision,
        SpanKind::LearnerEpoch,
        SpanKind::HttpParse,
        SpanKind::HttpWrite,
        SpanKind::Migration,
    ];

    /// Stable snake_case name (trace events, attribution tables).
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::QueueWait => "queue_wait",
            SpanKind::Admission => "admission",
            SpanKind::DraftWave => "draft_wave",
            SpanKind::Gemv => "gemv",
            SpanKind::VerifyCall => "verify",
            SpanKind::Commit => "commit",
            SpanKind::Finalize => "finalize",
            SpanKind::SchedulerDecision => "scheduler",
            SpanKind::LearnerEpoch => "learner_epoch",
            SpanKind::HttpParse => "http_parse",
            SpanKind::HttpWrite => "http_write",
            SpanKind::Migration => "migration",
        }
    }

    /// True when concurrent instances of this kind may overlap in time
    /// on one lane (exported as `ph:"X"` instead of nested B/E pairs).
    pub fn overlaps(self) -> bool {
        matches!(self, SpanKind::QueueWait)
    }
}

/// Optional attributes attached to a span (``NO_ATTR`` = absent).
#[derive(Debug, Clone, Copy)]
pub struct Attrs {
    /// Session id.
    pub session: u32,
    /// Segment index within the session.
    pub segment: u32,
    /// Speculative round index (or learner epoch for `LearnerEpoch`).
    pub round: u32,
    /// Scheduler policy epoch the work ran under.
    pub policy_epoch: u32,
    /// Fused-call occupancy (wave size / verify batch size).
    pub count: u32,
    /// Lane override; ``NO_ATTR`` records on the recorder's own lane.
    pub lane: u32,
}

impl Attrs {
    /// All attributes absent.
    pub const NONE: Attrs = Attrs {
        session: NO_ATTR,
        segment: NO_ATTR,
        round: NO_ATTR,
        policy_epoch: NO_ATTR,
        count: NO_ATTR,
        lane: NO_ATTR,
    };
}

impl Default for Attrs {
    fn default() -> Self {
        Attrs::NONE
    }
}

/// One recorded span: fixed-size, `Copy`, no heap.
#[derive(Debug, Clone, Copy)]
pub struct SpanEvent {
    /// Which stage this span measured.
    pub kind: SpanKind,
    /// Start, microseconds since the run's shared epoch.
    pub start_us: u64,
    /// End, microseconds since the run's shared epoch (≥ `start_us`).
    pub end_us: u64,
    /// Lane (Chrome-trace `tid`) the span belongs to.
    pub lane: u32,
    /// Attributes ([`NO_ATTR`] = absent).
    pub attrs: Attrs,
}

/// Wall-time distribution of one instrumented stage: streaming moments
/// plus a bounded reservoir for percentiles. Units are seconds.
#[derive(Debug, Clone)]
pub struct StageDist {
    /// Streaming count / mean / min / max.
    pub stats: OnlineStats,
    /// Bounded percentile sample.
    pub reservoir: Reservoir,
}

impl StageDist {
    /// Empty distribution.
    pub fn new() -> Self {
        Self { stats: OnlineStats::new(), reservoir: Reservoir::new(STAGE_RESERVOIR_CAP) }
    }

    /// Fold in one stage duration (seconds).
    pub fn push(&mut self, secs: f64) {
        self.stats.push(secs);
        self.reservoir.push(secs);
    }

    /// Merge another distribution (fleet aggregation).
    pub fn merge(&mut self, other: &StageDist) {
        self.stats.merge(&other.stats);
        self.reservoir.merge(&other.reservoir);
    }
}

impl Default for StageDist {
    fn default() -> Self {
        Self::new()
    }
}

/// Bounded per-thread span recorder (see module docs).
#[derive(Debug)]
pub struct SpanRecorder {
    enabled: bool,
    epoch: Instant,
    lane: u32,
    cap: usize,
    /// Ring storage; grows to `cap` then wraps at `next`.
    ring: Vec<SpanEvent>,
    /// Overwrite cursor once the ring is full.
    next: usize,
    /// Events overwritten after the ring filled.
    dropped: u64,
    /// Per-kind attribution, indexed by position in [`SpanKind::ALL`].
    stages: Vec<StageDist>,
}

impl SpanRecorder {
    /// Recorder on `lane`, timestamping relative to `epoch`. When
    /// `enabled` is false nothing is ever allocated or recorded.
    pub fn new(epoch: Instant, lane: u32, cap: usize, enabled: bool) -> Self {
        let cap = cap.max(1);
        let stages = if enabled {
            SpanKind::ALL.iter().map(|_| StageDist::new()).collect()
        } else {
            Vec::new()
        };
        Self { enabled, epoch, lane, cap, ring: Vec::new(), next: 0, dropped: 0, stages }
    }

    /// A permanently disabled recorder (every call is a no-op).
    pub fn disabled() -> Self {
        Self::new(Instant::now(), 0, 1, false)
    }

    /// Whether recording is active.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Start a span: reads the clock only when enabled. Call sites pair
    /// this with [`SpanRecorder::record`]; a `None` start is ignored
    /// there, so the disabled hot path performs no clock reads.
    pub fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a span opened by [`SpanRecorder::start`] at "now".
    pub fn record(&mut self, kind: SpanKind, start: Option<Instant>, attrs: Attrs) {
        let Some(start) = start else { return };
        if !self.enabled {
            return;
        }
        self.record_between(kind, start, Instant::now(), attrs);
    }

    /// Record a span with explicit endpoints (e.g. queue wait measured
    /// from the request's submission instant to its admission).
    pub fn record_between(&mut self, kind: SpanKind, start: Instant, end: Instant, attrs: Attrs) {
        if !self.enabled {
            return;
        }
        let start_us = self.micros(start);
        let end_us = self.micros(end).max(start_us);
        self.stages[kind_index(kind)].push((end_us - start_us) as f64 * 1e-6);
        let lane = if attrs.lane == NO_ATTR { self.lane } else { attrs.lane };
        let ev = SpanEvent { kind, start_us, end_us, lane, attrs };
        if self.ring.len() < self.cap {
            self.ring.push(ev);
        } else {
            self.ring[self.next] = ev;
            self.next = (self.next + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Microseconds since the shared epoch (saturating).
    fn micros(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_micros() as u64
    }

    /// Retained events in record order (oldest first).
    pub fn events(&self) -> Vec<SpanEvent> {
        let mut out = Vec::with_capacity(self.ring.len());
        out.extend_from_slice(&self.ring[self.next..]);
        out.extend_from_slice(&self.ring[..self.next]);
        out
    }

    /// Number of retained events (≤ capacity).
    pub fn len(&self) -> usize {
        self.ring.len()
    }

    /// True when nothing is retained.
    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Events overwritten after the ring filled.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Per-stage attribution observed by this recorder (kinds with at
    /// least one sample).
    pub fn stage_dists(&self) -> Vec<(SpanKind, &StageDist)> {
        SpanKind::ALL
            .iter()
            .zip(self.stages.iter())
            .filter(|(_, d)| d.stats.count() > 0)
            .map(|(&k, d)| (k, d))
            .collect()
    }
}

fn kind_index(kind: SpanKind) -> usize {
    SpanKind::ALL.iter().position(|&k| k == kind).expect("kind listed in ALL")
}

/// Shared recorder for low-rate producers (session drivers, learner).
///
/// The mutex is taken once per recorded span — session drivers record
/// one scheduler decision per segment and the learner one span per
/// epoch, so the lock is uncontended in practice. `enabled` is checked
/// without locking.
#[derive(Debug)]
pub struct SpanSink {
    enabled: bool,
    inner: Mutex<SpanRecorder>,
}

impl SpanSink {
    /// Shared sink timestamping against `epoch`.
    pub fn new(epoch: Instant, cap: usize, enabled: bool) -> Self {
        Self { enabled, inner: Mutex::new(SpanRecorder::new(epoch, LEARNER_LANE, cap, enabled)) }
    }

    /// Whether recording is active (lock-free).
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Start a span (`None` when disabled — no clock read, no lock).
    pub fn start(&self) -> Option<Instant> {
        if self.enabled {
            Some(Instant::now())
        } else {
            None
        }
    }

    /// Close a span opened by [`SpanSink::start`]. `attrs.lane` should
    /// be set ([`session_lane`] / [`LEARNER_LANE`]) so concurrent
    /// producers land on their own trace rows.
    pub fn record(&self, kind: SpanKind, start: Option<Instant>, attrs: Attrs) {
        let Some(start) = start else { return };
        if !self.enabled {
            return;
        }
        let end = Instant::now();
        let mut rec = self.inner.lock().expect("span sink poisoned");
        rec.record_between(kind, start, end, attrs);
    }

    /// Drain the sink: events, overwritten-count, and attribution.
    pub fn drain(&self) -> (Vec<SpanEvent>, u64, Vec<(SpanKind, StageDist)>) {
        let rec = self.inner.lock().expect("span sink poisoned");
        let dists = rec.stage_dists().into_iter().map(|(k, d)| (k, d.clone())).collect();
        (rec.events(), rec.dropped(), dists)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn disabled_recorder_is_inert() {
        let mut rec = SpanRecorder::disabled();
        assert!(!rec.enabled());
        assert!(rec.start().is_none());
        rec.record(SpanKind::Admission, rec.start(), Attrs::NONE);
        let epoch = Instant::now();
        rec.record_between(SpanKind::Admission, epoch, epoch, Attrs::NONE);
        assert!(rec.is_empty());
        assert_eq!(rec.dropped(), 0);
        assert!(rec.stage_dists().is_empty());
    }

    #[test]
    fn ring_bounds_memory_and_counts_drops() {
        let epoch = Instant::now();
        let mut rec = SpanRecorder::new(epoch, 7, 8, true);
        for i in 0..20u64 {
            let t = epoch + Duration::from_micros(10 * i);
            rec.record_between(SpanKind::Gemv, t, t + Duration::from_micros(5), Attrs::NONE);
        }
        assert_eq!(rec.len(), 8, "ring never exceeds capacity");
        assert_eq!(rec.dropped(), 12);
        // Oldest-first linearization: the 8 newest events survive.
        let evs = rec.events();
        assert_eq!(evs.len(), 8);
        let starts: Vec<u64> = evs.iter().map(|e| e.start_us).collect();
        let expect: Vec<u64> = (12..20).map(|i| 10 * i).collect();
        assert_eq!(starts, expect);
        // Attribution saw every observation, not just the retained ring.
        let dists = rec.stage_dists();
        assert_eq!(dists.len(), 1);
        assert_eq!(dists[0].0, SpanKind::Gemv);
        assert_eq!(dists[0].1.stats.count(), 20);
        assert!((dists[0].1.stats.mean() - 5e-6).abs() < 1e-9);
    }

    #[test]
    fn explicit_interval_and_attrs_round_trip() {
        let epoch = Instant::now();
        let mut rec = SpanRecorder::new(epoch, 3, 16, true);
        let s = epoch + Duration::from_micros(100);
        let e = epoch + Duration::from_micros(350);
        rec.record_between(
            SpanKind::QueueWait,
            s,
            e,
            Attrs { session: 4, segment: 2, lane: queue_lane(3), ..Attrs::NONE },
        );
        let ev = rec.events()[0];
        assert_eq!(ev.kind, SpanKind::QueueWait);
        assert_eq!(ev.start_us, 100);
        assert_eq!(ev.end_us, 350);
        assert_eq!(ev.lane, queue_lane(3));
        assert_eq!(ev.attrs.session, 4);
        assert_eq!(ev.attrs.segment, 2);
        assert_eq!(ev.attrs.round, NO_ATTR);
        // End before start saturates to a zero-length span, never panics.
        rec.record_between(SpanKind::Admission, e, s, Attrs::NONE);
        let ev = rec.events()[1];
        assert_eq!(ev.start_us, ev.end_us);
    }

    #[test]
    fn sink_is_shared_and_drains() {
        let sink = SpanSink::new(Instant::now(), 16, true);
        let t = sink.start();
        assert!(t.is_some());
        sink.record(
            SpanKind::SchedulerDecision,
            t,
            Attrs { session: 1, lane: session_lane(1), ..Attrs::NONE },
        );
        sink.record(SpanKind::LearnerEpoch, sink.start(), Attrs { round: 7, ..Attrs::NONE });
        let (evs, dropped, dists) = sink.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(dropped, 0);
        assert_eq!(dists.len(), 2);
        assert_eq!(evs[0].lane, session_lane(1));
        assert_eq!(evs[1].lane, LEARNER_LANE);
        let disabled = SpanSink::new(Instant::now(), 16, false);
        assert!(disabled.start().is_none());
        disabled.record(SpanKind::LearnerEpoch, disabled.start(), Attrs::NONE);
        assert!(disabled.drain().0.is_empty());
    }

    #[test]
    fn lane_names_cover_ranges() {
        assert_eq!(lane_name(shard_lane(2)), "shard 2");
        assert_eq!(lane_name(queue_lane(0)), "shard 0 queue");
        assert_eq!(lane_name(session_lane(5)), "session 5");
        assert_eq!(lane_name(LEARNER_LANE), "learner");
        assert_eq!(lane_name(FLEET_LANE), "fleet");
        assert_eq!(lane_name(http_lane(3)), "http conn 3");
    }

    #[test]
    fn every_kind_is_listed_and_named() {
        // kind_index relies on ALL being exhaustive; a variant missing
        // from ALL would panic the recorder on first use.
        for (i, k) in SpanKind::ALL.iter().enumerate() {
            assert_eq!(kind_index(*k), i);
            assert!(!k.name().is_empty());
        }
        assert_eq!(SpanKind::HttpParse.name(), "http_parse");
        assert_eq!(SpanKind::HttpWrite.name(), "http_write");
        assert!(!SpanKind::HttpParse.overlaps());
    }
}
