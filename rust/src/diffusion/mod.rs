//! DDPM math on the request path.
//!
//! Three pieces, mirroring §3.1–3.2 of the paper:
//! - [`schedule`]: the DDPM noise schedule and posterior (the Rust twin of
//!   `python/compile/ddpm.py`; parity is enforced by a golden-value test).
//! - [`acceptance`]: the Metropolis–Hastings draft acceptance test
//!   (Eq. 10–11).
//! - [`coupling`]: reflection-maximal coupling used to correct the first
//!   rejected draft (Eq. 4–6) so the committed sample still follows the
//!   target distribution — this is what makes the acceleration lossless.

pub mod acceptance;
pub mod coupling;
pub mod schedule;

pub use acceptance::{accept_draft, log_accept_ratio, AcceptMode};
pub use coupling::reflection_couple;
pub use schedule::DdpmSchedule;
