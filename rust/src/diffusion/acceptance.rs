//! Metropolis–Hastings draft acceptance (paper Eq. 10–11).
//!
//! A draft sample was generated as x = μ̂ + σ·ξ from the drafter's
//! posterior; the target model's posterior at the same point has mean μ.
//! With shared isotropic σ the log acceptance ratio reduces to
//!
//!   log α = −½‖d‖² − ⟨d, ξ⟩,   d = (μ̂ − μ)/σ,
//!
//! and p = min(1, exp(log α)). The paper accepts when p ≥ λ with λ a
//! scheduler-tuned threshold (deterministic mode); classic speculative
//! sampling instead draws U ~ Unif(0,1) and accepts when U ≤ p
//! (stochastic mode). Both are provided; TS-DP uses the threshold.

use crate::util::Rng;

/// How the acceptance probability is turned into an accept/reject bit.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AcceptMode {
    /// Accept iff p ≥ λ (paper §3.2; λ emitted by the scheduler).
    Threshold(f32),
    /// Accept iff U ≤ p with U ~ Unif(0,1) (classic lossless test).
    Stochastic,
}

/// Eq. 10: log acceptance ratio for one draft.
///
/// `mu_draft` = drafter posterior mean μ̂, `mu_target` = target posterior
/// mean μ, `sigma` = effective (possibly scheduler-scaled) std, `xi` = the
/// standard-normal draw that produced the draft sample.
pub fn log_accept_ratio(mu_draft: &[f32], mu_target: &[f32], sigma: f32, xi: &[f32]) -> f64 {
    debug_assert_eq!(mu_draft.len(), mu_target.len());
    debug_assert_eq!(mu_draft.len(), xi.len());
    let sigma = sigma.max(1e-8) as f64;
    let mut quad = 0.0f64;
    let mut cross = 0.0f64;
    for i in 0..mu_draft.len() {
        let d = (mu_draft[i] as f64 - mu_target[i] as f64) / sigma;
        quad += d * d;
        cross += d * xi[i] as f64;
    }
    -0.5 * quad - cross
}

/// Eq. 11: acceptance probability p = min(1, exp(log α)).
pub fn accept_prob(log_alpha: f64) -> f64 {
    log_alpha.min(0.0).exp()
}

/// Full accept/reject decision. Returns `(accepted, p)`.
pub fn accept_draft(
    mu_draft: &[f32],
    mu_target: &[f32],
    sigma: f32,
    xi: &[f32],
    mode: AcceptMode,
    rng: &mut Rng,
) -> (bool, f64) {
    let p = accept_prob(log_accept_ratio(mu_draft, mu_target, sigma, xi));
    let accepted = match mode {
        AcceptMode::Threshold(lambda) => p >= lambda as f64,
        AcceptMode::Stochastic => (rng.uniform() as f64) <= p,
    };
    (accepted, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{assert_close, check_property};

    #[test]
    fn identical_means_always_accept() {
        let mu = vec![0.3, -0.5, 0.9];
        let xi = vec![1.0, -2.0, 0.5];
        let la = log_accept_ratio(&mu, &mu, 0.1, &xi);
        assert_eq!(la, 0.0);
        assert_eq!(accept_prob(la), 1.0);
    }

    #[test]
    fn matches_closed_form_1d() {
        // d = (0.2 - 0.1)/0.5 = 0.2; log α = -0.5*0.04 - 0.2*ξ.
        let la = log_accept_ratio(&[0.2], &[0.1], 0.5, &[1.5]);
        assert_close(la as f32, -0.5 * 0.04 - 0.2 * 1.5, 1e-5);
    }

    #[test]
    fn threshold_mode_is_deterministic() {
        let mut rng = Rng::seed_from_u64(0);
        let (a1, p1) =
            accept_draft(&[0.11], &[0.1], 1.0, &[0.0], AcceptMode::Threshold(0.5), &mut rng);
        let (a2, p2) =
            accept_draft(&[0.11], &[0.1], 1.0, &[0.0], AcceptMode::Threshold(0.5), &mut rng);
        assert_eq!(a1, a2);
        assert_eq!(p1, p2);
        assert!(a1, "tiny mean gap, wide sigma -> p ~ 1");
    }

    #[test]
    fn larger_sigma_raises_acceptance_of_mismatched_means() {
        // Fig. 3b: widening σ rescues acceptance when means disagree.
        let mu_d = vec![0.5; 8];
        let mu_t = vec![0.0; 8];
        let xi = vec![0.3; 8];
        let p_narrow = accept_prob(log_accept_ratio(&mu_d, &mu_t, 0.1, &xi));
        let p_wide = accept_prob(log_accept_ratio(&mu_d, &mu_t, 2.0, &xi));
        assert!(p_wide > p_narrow);
    }

    #[test]
    fn stochastic_mode_accept_rate_tracks_p() {
        // Choose d so that with ξ = 0: p = exp(-0.5 d²) = 0.5 → d = sqrt(2 ln 2).
        let d = (2.0 * std::f64::consts::LN_2).sqrt() as f32;
        let mut rng = Rng::seed_from_u64(42);
        let mut acc = 0;
        let n = 20_000;
        for _ in 0..n {
            let (a, p) = accept_draft(&[d], &[0.0], 1.0, &[0.0], AcceptMode::Stochastic, &mut rng);
            assert_close(p as f32, 0.5, 1e-5);
            acc += a as usize;
        }
        let rate = acc as f64 / n as f64;
        assert!((rate - 0.5).abs() < 0.02, "rate={rate}");
    }

    /// p is a probability and is monotonically non-increasing in the
    /// mean gap (with ξ = 0).
    #[test]
    fn prop_p_is_valid_and_monotone() {
        check_property("p_valid_monotone", 200, |rng| {
            let gap = rng.uniform_range(0.0, 5.0);
            let sigma = rng.uniform_range(0.05, 4.0);
            let xi = [0.0f32; 4];
            let mu_t = [0.0f32; 4];
            let mu_d = [gap; 4];
            let p = accept_prob(log_accept_ratio(&mu_d, &mu_t, sigma, &xi));
            assert!((0.0..=1.0).contains(&p));
            let mu_d2 = [gap + 0.1; 4];
            let p2 = accept_prob(log_accept_ratio(&mu_d2, &mu_t, sigma, &xi));
            assert!(p2 <= p + 1e-12);
        });
    }

    /// Invariance: scaling both the gap and sigma by the same factor
    /// leaves log α unchanged (d is scale-free) when ξ = 0.
    #[test]
    fn prop_scale_invariance() {
        check_property("scale_invariance", 200, |rng| {
            let gap = rng.uniform_range(0.01, 2.0);
            let s = rng.uniform_range(0.1, 4.0);
            let c = rng.uniform_range(0.5, 3.0);
            let la1 = log_accept_ratio(&[gap], &[0.0], s, &[0.0]);
            let la2 = log_accept_ratio(&[gap * c], &[0.0], s * c, &[0.0]);
            assert!((la1 - la2).abs() < 1e-4, "{la1} vs {la2}");
        });
    }
}
