//! DDPM noise schedule and posterior, matching `python/compile/ddpm.py`.
//!
//! Diffusion Policy uses the `squaredcos_cap_v2` (cosine) beta schedule
//! with sample clipping; we reproduce exactly that so the Rust request
//! path and the JAX training/export path agree bit-for-bit (up to f32
//! rounding) — see `rust/tests/ddpm_parity.rs` and
//! `python/tests/test_ddpm.py`, which check both sides against the same
//! golden values.

/// Range actions are normalized into; predicted x0 is clipped here, as in
/// Diffusion Policy's `clip_sample=True`.
pub const CLIP: f32 = 1.0;

/// Precomputed DDPM schedule quantities for `n` denoising steps.
#[derive(Debug, Clone)]
pub struct DdpmSchedule {
    /// β_t.
    pub betas: Vec<f32>,
    /// α_t = 1 − β_t.
    pub alphas: Vec<f32>,
    /// ᾱ_t = Π α.
    pub alpha_bars: Vec<f32>,
    /// Posterior standard deviation σ_t (0 at t = 0).
    pub sigmas: Vec<f32>,
}

impl DdpmSchedule {
    /// Cosine (squaredcos_cap_v2) schedule over `n` steps.
    pub fn cosine(n: usize) -> Self {
        let alpha_bar_fn =
            |u: f64| ((u + 0.008) / 1.008 * std::f64::consts::FRAC_PI_2).cos().powi(2);
        let mut betas = Vec::with_capacity(n);
        for t in 0..n {
            let a0 = alpha_bar_fn(t as f64 / n as f64);
            let a1 = alpha_bar_fn((t + 1) as f64 / n as f64);
            betas.push(((1.0 - a1 / a0).min(0.999)) as f32);
        }
        Self::from_betas(betas)
    }

    /// Build all derived quantities from β.
    pub fn from_betas(betas: Vec<f32>) -> Self {
        let n = betas.len();
        let alphas: Vec<f32> = betas.iter().map(|b| 1.0 - b).collect();
        let mut alpha_bars = Vec::with_capacity(n);
        let mut prod = 1.0f32;
        for a in &alphas {
            prod *= a;
            alpha_bars.push(prod);
        }
        let mut sigmas = Vec::with_capacity(n);
        for t in 0..n {
            if t == 0 {
                sigmas.push(0.0);
            } else {
                let ab_prev = alpha_bars[t - 1];
                let var = betas[t] * (1.0 - ab_prev) / (1.0 - alpha_bars[t]);
                sigmas.push(var.max(0.0).sqrt());
            }
        }
        Self { betas, alphas, alpha_bars, sigmas }
    }

    /// Number of steps.
    pub fn len(&self) -> usize {
        self.betas.len()
    }

    /// True for an empty schedule (never constructed in practice).
    pub fn is_empty(&self) -> bool {
        self.betas.is_empty()
    }

    /// ᾱ_{t−1}, with ᾱ_{−1} = 1.
    pub fn alpha_bar_prev(&self, t: usize) -> f32 {
        if t == 0 {
            1.0
        } else {
            self.alpha_bars[t - 1]
        }
    }

    /// Predicted clean sample x̂0 from the ε-prediction at step `t`
    /// (clipped to ±CLIP, matching Diffusion Policy).
    pub fn predict_x0(&self, t: usize, x_t: &[f32], eps: &[f32], out: &mut [f32]) {
        let ab = self.alpha_bars[t];
        let s_ab = ab.sqrt();
        let s_1mab = (1.0 - ab).sqrt();
        for i in 0..x_t.len() {
            out[i] = ((x_t[i] - s_1mab * eps[i]) / s_ab).clamp(-CLIP, CLIP);
        }
    }

    /// Posterior mean μ_t(x_t, x̂0) of q(x_{t−1} | x_t, x̂0).
    pub fn posterior_mean(&self, t: usize, x_t: &[f32], x0: &[f32], out: &mut [f32]) {
        let ab = self.alpha_bars[t];
        let ab_prev = self.alpha_bar_prev(t);
        let beta = self.betas[t];
        let alpha = self.alphas[t];
        let c0 = ab_prev.sqrt() * beta / (1.0 - ab);
        let ct = alpha.sqrt() * (1.0 - ab_prev) / (1.0 - ab);
        for i in 0..x_t.len() {
            out[i] = c0 * x0[i] + ct * x_t[i];
        }
    }

    /// Full DDPM reverse step: ε-prediction → posterior mean; the caller
    /// supplies the standard-normal draw `xi` (retained for the
    /// verification stage, per §3.2 "Draft Generation Procedure").
    /// Returns (x_{t−1}, μ_t).
    pub fn step(&self, t: usize, x_t: &[f32], eps: &[f32], xi: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let d = x_t.len();
        let mut x0 = vec![0.0; d];
        let mut x_prev = vec![0.0; d];
        let mut mean = vec![0.0; d];
        self.step_into(t, x_t, eps, xi, &mut x0, &mut x_prev, &mut mean);
        (x_prev, mean)
    }

    /// Allocation-free reverse step: like [`Self::step`] but writes into
    /// caller-owned buffers (`x0_scratch` holds the intermediate x̂0).
    /// Used by the speculative job's draft fallback so a serial rollout
    /// performs no per-draft heap allocation.
    #[allow(clippy::too_many_arguments)]
    pub fn step_into(
        &self,
        t: usize,
        x_t: &[f32],
        eps: &[f32],
        xi: &[f32],
        x0_scratch: &mut [f32],
        x_prev: &mut [f32],
        mean: &mut [f32],
    ) {
        self.predict_x0(t, x_t, eps, x0_scratch);
        self.posterior_mean(t, x_t, x0_scratch, mean);
        let sigma = self.sigmas[t];
        for i in 0..x_t.len() {
            x_prev[i] = mean[i] + sigma * xi[i];
        }
    }

    /// Forward noising: x_t = √ᾱ_t · x0 + √(1−ᾱ_t) · ε (used by tests and
    /// the demo-replay tooling; training does this on the JAX side).
    pub fn add_noise(&self, t: usize, x0: &[f32], eps: &[f32], out: &mut [f32]) {
        let ab = self.alpha_bars[t];
        let (a, b) = (ab.sqrt(), (1.0 - ab).sqrt());
        for i in 0..x0.len() {
            out[i] = a * x0[i] + b * eps[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::assert_close;

    #[test]
    fn cosine_schedule_is_monotone_and_bounded() {
        let s = DdpmSchedule::cosine(100);
        assert_eq!(s.len(), 100);
        for t in 0..100 {
            assert!(s.betas[t] > 0.0 && s.betas[t] <= 0.999);
            assert!(s.alpha_bars[t] > 0.0 && s.alpha_bars[t] < 1.0);
            if t > 0 {
                assert!(s.alpha_bars[t] < s.alpha_bars[t - 1], "alpha_bar must decrease");
            }
        }
        // By the end of forward diffusion nearly all signal is destroyed.
        assert!(s.alpha_bars[99] < 1e-3);
    }

    #[test]
    fn sigma_zero_at_final_step_only() {
        let s = DdpmSchedule::cosine(100);
        assert_eq!(s.sigmas[0], 0.0);
        for t in 1..100 {
            assert!(s.sigmas[t] > 0.0);
        }
    }

    #[test]
    fn perfect_eps_recovers_x0() {
        // If ε is exactly the noise used in add_noise, predict_x0 inverts it.
        let s = DdpmSchedule::cosine(100);
        let x0 = [0.3, -0.7, 0.9, 0.0];
        let eps = [0.5, -1.2, 0.1, 2.0];
        for t in [0, 10, 50, 99] {
            let mut xt = [0.0; 4];
            s.add_noise(t, &x0, &eps, &mut xt);
            let mut rec = [0.0; 4];
            s.predict_x0(t, &xt, &eps, &mut rec);
            for i in 0..4 {
                assert_close(rec[i], x0[i], 2e-3);
            }
        }
    }

    #[test]
    fn x0_prediction_is_clipped() {
        let s = DdpmSchedule::cosine(100);
        let xt = [10.0f32];
        let eps = [0.0f32];
        let mut out = [0.0f32];
        s.predict_x0(50, &xt, &eps, &mut out);
        assert_eq!(out[0], CLIP);
    }

    #[test]
    fn step_at_t0_is_deterministic() {
        let s = DdpmSchedule::cosine(100);
        let xt = [0.2, -0.4];
        let eps = [0.1, 0.1];
        let (a, mean_a) = s.step(0, &xt, &eps, &[5.0, -5.0]);
        let (b, mean_b) = s.step(0, &xt, &eps, &[0.0, 0.0]);
        assert_eq!(a, b, "sigma_0 = 0 makes the last step deterministic");
        assert_eq!(mean_a, mean_b);
    }

    #[test]
    fn posterior_mean_interpolates_x0_and_xt() {
        // Coefficients must sum to ~sqrt-consistent weights; sanity: with
        // x0 == x_t == c, mean ≈ c (both coefficients sum to ≈1 for small β).
        // The ≈c identity only holds where β is small: the cosine
        // schedule's β explodes toward t = n−1 (capped at 0.999), where
        // the posterior legitimately shrinks toward x̂0's coefficient.
        let s = DdpmSchedule::cosine(100);
        for t in 1..100 {
            if s.betas[t] > 0.05 {
                continue;
            }
            let c = 0.5f32;
            let mut mean = [0.0f32];
            s.posterior_mean(t, &[c], &[c], &mut mean);
            assert_close(mean[0], c, 2e-2);
        }
    }

    #[test]
    fn full_reverse_trajectory_stays_finite() {
        let s = DdpmSchedule::cosine(100);
        let mut rng = crate::util::Rng::seed_from_u64(0);
        let mut x: Vec<f32> = rng.normal_vec(8);
        for t in (0..100).rev() {
            let eps: Vec<f32> = x.clone(); // degenerate ε-model: predict x_t
            let xi = rng.normal_vec(8);
            let (next, _) = s.step(t, &x, &eps, &xi);
            x = next;
            for v in &x {
                assert!(v.is_finite());
            }
        }
        // With ε̂ = x_t the implied x̂0 is pulled toward 0 and clipped; the
        // trajectory must end bounded.
        for v in &x {
            assert!(v.abs() <= 3.0);
        }
    }
}
