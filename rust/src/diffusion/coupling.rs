//! Reflection-maximal coupling (paper Eq. 4–6).
//!
//! When the first draft in a speculative window is rejected, TS-DP does
//! not re-invoke the target model: it *corrects* the already-drawn draft
//! sample x̃ ~ N(m_r, σ²I) into a sample exactly distributed as the
//! target N(m_s, σ²I) by reflecting it across the hyperplane orthogonal
//! to Δ = m_r − m_s:
//!
//!   x = m_s + (I − 2·e·eᵀ)(x̃ − m_r),  e = Δ/‖Δ‖.
//!
//! Combined with the maximal-coupling accept step (Eq. 5) the output
//! marginal is exactly N(m_s, σ²I) while staying as close as possible to
//! the rejected draft — preserving the stochasticity the rest of the
//! trajectory was conditioned on.

use crate::util::math::dot;
use crate::util::Rng;

/// Outcome of one reflection-maximal-coupling correction.
#[derive(Debug, Clone)]
pub struct CoupleResult {
    /// The corrected sample, marginally ~ N(m_s, σ²I).
    pub sample: Vec<f32>,
    /// Whether the draft was accepted as-is by the maximal-coupling test
    /// (Eq. 5) rather than reflected.
    pub coupled: bool,
}

/// Correct a rejected draft sample via reflection-maximal coupling.
///
/// * `x_draft` — the rejected draft sample x̃ ~ N(m_r, σ²I)
/// * `m_r` — drafter posterior mean
/// * `m_s` — target posterior mean
/// * `sigma` — shared isotropic standard deviation
pub fn reflection_couple(
    x_draft: &[f32],
    m_r: &[f32],
    m_s: &[f32],
    sigma: f32,
    rng: &mut Rng,
) -> CoupleResult {
    let d = x_draft.len();
    debug_assert_eq!(m_r.len(), d);
    debug_assert_eq!(m_s.len(), d);
    let sigma = sigma.max(1e-8);

    // Degenerate case: identical means — the draft already has the target
    // distribution.
    let delta: Vec<f32> = m_r.iter().zip(m_s).map(|(r, s)| r - s).collect();
    let delta_norm = dot(&delta, &delta).sqrt();
    if delta_norm < 1e-12 {
        return CoupleResult { sample: x_draft.to_vec(), coupled: true };
    }

    // Maximal-coupling accept test (Eq. 5):
    //   log s(x̃)/r(x̃) = (‖x̃−m_r‖² − ‖x̃−m_s‖²) / (2σ²)
    let mut d_r2 = 0.0f64;
    let mut d_s2 = 0.0f64;
    for i in 0..d {
        let dr = (x_draft[i] - m_r[i]) as f64;
        let ds = (x_draft[i] - m_s[i]) as f64;
        d_r2 += dr * dr;
        d_s2 += ds * ds;
    }
    let log_ratio = (d_r2 - d_s2) / (2.0 * (sigma as f64) * (sigma as f64));
    let u = rng.uniform() as f64;
    if u.ln() <= log_ratio {
        return CoupleResult { sample: x_draft.to_vec(), coupled: true };
    }

    // Reflection (Eq. 6): x = m_s + (I − 2eeᵀ)(x̃ − m_r).
    let e: Vec<f32> = delta.iter().map(|x| x / delta_norm).collect();
    let z: Vec<f32> = x_draft.iter().zip(m_r).map(|(x, m)| x - m).collect();
    let proj = dot(&e, &z);
    let sample: Vec<f32> =
        (0..d).map(|i| m_s[i] + z[i] - 2.0 * proj * e[i]).collect();
    CoupleResult { sample, coupled: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::math::{mean, std_dev};

    /// Draw x̃ ~ N(m_r, σ²) then couple; the output marginal must be
    /// N(m_s, σ²). Checked via sample moments per dimension.
    #[test]
    fn output_marginal_matches_target() {
        let m_r = vec![1.0f32, -0.5, 0.0];
        let m_s = vec![0.2f32, 0.3, -0.1];
        let sigma = 0.7f32;
        let n = 40_000;
        let mut rng = Rng::seed_from_u64(9);
        let mut dims: Vec<Vec<f32>> = vec![Vec::with_capacity(n); 3];
        for _ in 0..n {
            let draft: Vec<f32> =
                (0..3).map(|i| m_r[i] + sigma * rng.normal()).collect();
            let out = reflection_couple(&draft, &m_r, &m_s, sigma, &mut rng);
            for (i, v) in out.sample.iter().enumerate() {
                dims[i].push(*v);
            }
        }
        for i in 0..3 {
            let m = mean(&dims[i]);
            let s = std_dev(&dims[i]);
            assert!((m - m_s[i]).abs() < 0.02, "dim {i} mean {m} vs {}", m_s[i]);
            assert!((s - sigma).abs() < 0.02, "dim {i} std {s} vs {sigma}");
        }
    }

    /// Coupling probability equals the total-variation overlap of the two
    /// Gaussians: P(couple) = 2·Φ(−‖Δ‖/(2σ)).
    #[test]
    fn coupling_probability_matches_theory() {
        let m_r = vec![0.5f32];
        let m_s = vec![0.0f32];
        let sigma = 1.0f32;
        let n = 60_000;
        let mut rng = Rng::seed_from_u64(10);
        let mut coupled = 0usize;
        for _ in 0..n {
            let draft = vec![m_r[0] + sigma * rng.normal()];
            let out = reflection_couple(&draft, &m_r, &m_s, sigma, &mut rng);
            coupled += out.coupled as usize;
        }
        let rate = coupled as f64 / n as f64;
        // Φ(−0.25) ≈ 0.40129 → theory ≈ 0.80258
        let theory = 2.0 * 0.401294;
        assert!((rate - theory).abs() < 0.01, "rate={rate} theory={theory}");
    }

    #[test]
    fn identical_means_keep_draft() {
        let mut rng = Rng::seed_from_u64(1);
        let x = vec![0.1, 0.2];
        let m = vec![0.0, 0.0];
        let out = reflection_couple(&x, &m, &m, 1.0, &mut rng);
        assert!(out.coupled);
        assert_eq!(out.sample, x);
    }

    /// The reflection is an isometry: ‖x − m_s‖ = ‖x̃ − m_r‖ for reflected
    /// outputs.
    #[test]
    fn reflection_preserves_radius() {
        let mut rng = Rng::seed_from_u64(2);
        let m_r = vec![2.0f32, 0.0];
        let m_s = vec![-2.0f32, 0.0];
        for _ in 0..200 {
            let draft: Vec<f32> = (0..2).map(|i| m_r[i] + rng.normal()).collect();
            let out = reflection_couple(&draft, &m_r, &m_s, 1.0, &mut rng);
            if !out.coupled {
                let r_in: f32 =
                    draft.iter().zip(&m_r).map(|(x, m)| (x - m) * (x - m)).sum::<f32>().sqrt();
                let r_out: f32 = out
                    .sample
                    .iter()
                    .zip(&m_s)
                    .map(|(x, m)| (x - m) * (x - m))
                    .sum::<f32>()
                    .sqrt();
                assert!((r_in - r_out).abs() < 1e-4, "{r_in} vs {r_out}");
            }
        }
    }

    /// Far-apart means almost never couple; output still follows target.
    #[test]
    fn distant_means_always_reflect() {
        let mut rng = Rng::seed_from_u64(3);
        let m_r = vec![50.0f32];
        let m_s = vec![-50.0f32];
        let mut coupled = 0;
        let mut vals = Vec::new();
        for _ in 0..5000 {
            let draft = vec![m_r[0] + 0.5 * rng.normal()];
            let out = reflection_couple(&draft, &m_r, &m_s, 0.5, &mut rng);
            coupled += out.coupled as usize;
            vals.push(out.sample[0]);
        }
        assert_eq!(coupled, 0);
        assert!((mean(&vals) - m_s[0]).abs() < 0.05);
        assert!((std_dev(&vals) - 0.5).abs() < 0.02);
    }
}
