//! The distilled drafter: a tiny one-block Transformer ε-predictor.
//!
//! Architecture (paper §3.1: the drafter is a single Transformer block
//! against the target's eight — hence the 1/8-NFE accounting):
//!
//! * **Tokens are denoising steps.** Token j of a rollout carries
//!   `(x_{t−j}, time_features(t−j), cond)`; causal self-attention lets
//!   step j condition on every earlier step of the *same* rollout, which
//!   is what makes a fused K-step rollout genuinely different from K
//!   independent single-step calls (and what the rollout-consistency
//!   loss trains — see `drafter::train`).
//! * **x̂0 parametrization.** The head predicts the clean sample x̂0
//!   (tanh-bounded, matching the schedule's `clip_sample` range) rather
//!   than ε directly; [`eps_from_x0`] converts at the [`Denoiser`]
//!   boundary. This preconditions the regression: raw ε targets blow up
//!   as √(1−ᾱ_t) → 0 in late denoising while x̂0 stays in [−1, 1], and
//!   the engine's accept test only ever sees ε through `predict_x0`, so
//!   the two parametrizations are equivalent at serve time.
//! * **Hand-rolled backprop** in the `scheduler::nn` style (no autograd
//!   crates exist here); gradients are finite-difference checked below.
//!
//! [`Denoiser`]: crate::policy::Denoiser

use crate::config::{ACT_DIM, DIFFUSION_STEPS, EMBED_DIM, HORIZON};
use crate::diffusion::DdpmSchedule;
use crate::drafter::layers::{
    linear_backward, softmax_inplace, time_features, LayerNorm, TIME_FEATS,
};
use crate::kernels::Kernels;
use crate::scheduler::nn::Linear;
use crate::util::json::Json;
use crate::util::math::{add_scaled, dot};
use crate::util::Rng;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// Flattened segment size (one token's latent).
const SEG: usize = HORIZON * ACT_DIM;

/// Width of the drafter's token embedding.
pub const D_MODEL: usize = 32;
/// Width of the feed-forward hidden layer.
pub const D_FF: usize = 64;
/// Token input width: latent ‖ timestep features ‖ conditioning.
pub const IN_DIM: usize = SEG + TIME_FEATS + EMBED_DIM;
/// Checkpoint format tag written into every saved drafter.
pub const CHECKPOINT_FORMAT: &str = "ts-dp-drafter-v1";

/// One-block causal Transformer over denoising-step tokens.
#[derive(Debug, Clone)]
pub struct DrafterModel {
    /// Token embedding: IN_DIM → D_MODEL.
    pub w_in: Linear,
    /// Pre-attention LayerNorm.
    pub ln1: LayerNorm,
    /// Query projection.
    pub wq: Linear,
    /// Key projection.
    pub wk: Linear,
    /// Value projection.
    pub wv: Linear,
    /// Attention output projection.
    pub wo: Linear,
    /// Pre-MLP LayerNorm.
    pub ln2: LayerNorm,
    /// Feed-forward up projection (tanh activation).
    pub w1: Linear,
    /// Feed-forward down projection.
    pub w2: Linear,
    /// Final LayerNorm before the head.
    pub lnf: LayerNorm,
    /// Output head: D_MODEL → SEG, tanh-squashed into the x̂0 range.
    pub w_out: Linear,
}

/// Per-sequence activation cache for [`DrafterModel::backward_seq`].
pub struct SeqCache {
    inputs: Vec<Vec<f32>>,
    e: Vec<Vec<f32>>,
    n1: Vec<Vec<f32>>,
    n1_stats: Vec<(f32, f32)>,
    q: Vec<Vec<f32>>,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    attn: Vec<Vec<f32>>,
    ctx: Vec<Vec<f32>>,
    h: Vec<Vec<f32>>,
    n2: Vec<Vec<f32>>,
    n2_stats: Vec<(f32, f32)>,
    f1: Vec<Vec<f32>>,
    z: Vec<Vec<f32>>,
    nf: Vec<Vec<f32>>,
    nf_stats: Vec<(f32, f32)>,
    y: Vec<Vec<f32>>,
}

/// Parameter gradients mirroring [`DrafterModel`]'s layout; each entry is
/// `(d_weights_or_gamma, d_bias_or_beta)`.
pub struct DrafterGrads {
    /// Token embedding grads.
    pub w_in: (Vec<f32>, Vec<f32>),
    /// Pre-attention LayerNorm grads.
    pub ln1: (Vec<f32>, Vec<f32>),
    /// Query grads.
    pub wq: (Vec<f32>, Vec<f32>),
    /// Key grads.
    pub wk: (Vec<f32>, Vec<f32>),
    /// Value grads.
    pub wv: (Vec<f32>, Vec<f32>),
    /// Attention output grads.
    pub wo: (Vec<f32>, Vec<f32>),
    /// Pre-MLP LayerNorm grads.
    pub ln2: (Vec<f32>, Vec<f32>),
    /// Feed-forward up grads.
    pub w1: (Vec<f32>, Vec<f32>),
    /// Feed-forward down grads.
    pub w2: (Vec<f32>, Vec<f32>),
    /// Final LayerNorm grads.
    pub lnf: (Vec<f32>, Vec<f32>),
    /// Output head grads.
    pub w_out: (Vec<f32>, Vec<f32>),
}

fn lin_zeros(l: &Linear) -> (Vec<f32>, Vec<f32>) {
    (vec![0.0; l.w.len()], vec![0.0; l.b.len()])
}

fn ln_zeros(l: &LayerNorm) -> (Vec<f32>, Vec<f32>) {
    (vec![0.0; l.gamma.len()], vec![0.0; l.beta.len()])
}

impl DrafterGrads {
    /// Zero gradients matching `m`'s shapes.
    pub fn zeros(m: &DrafterModel) -> Self {
        Self {
            w_in: lin_zeros(&m.w_in),
            ln1: ln_zeros(&m.ln1),
            wq: lin_zeros(&m.wq),
            wk: lin_zeros(&m.wk),
            wv: lin_zeros(&m.wv),
            wo: lin_zeros(&m.wo),
            ln2: ln_zeros(&m.ln2),
            w1: lin_zeros(&m.w1),
            w2: lin_zeros(&m.w2),
            lnf: ln_zeros(&m.lnf),
            w_out: lin_zeros(&m.w_out),
        }
    }

    fn views(&self) -> [&[f32]; 22] {
        [
            &self.w_in.0,
            &self.w_in.1,
            &self.ln1.0,
            &self.ln1.1,
            &self.wq.0,
            &self.wq.1,
            &self.wk.0,
            &self.wk.1,
            &self.wv.0,
            &self.wv.1,
            &self.wo.0,
            &self.wo.1,
            &self.ln2.0,
            &self.ln2.1,
            &self.w1.0,
            &self.w1.1,
            &self.w2.0,
            &self.w2.1,
            &self.lnf.0,
            &self.lnf.1,
            &self.w_out.0,
            &self.w_out.1,
        ]
    }

    fn views_mut(&mut self) -> [&mut Vec<f32>; 22] {
        [
            &mut self.w_in.0,
            &mut self.w_in.1,
            &mut self.ln1.0,
            &mut self.ln1.1,
            &mut self.wq.0,
            &mut self.wq.1,
            &mut self.wk.0,
            &mut self.wk.1,
            &mut self.wv.0,
            &mut self.wv.1,
            &mut self.wo.0,
            &mut self.wo.1,
            &mut self.ln2.0,
            &mut self.ln2.1,
            &mut self.w1.0,
            &mut self.w1.1,
            &mut self.w2.0,
            &mut self.w2.1,
            &mut self.lnf.0,
            &mut self.lnf.1,
            &mut self.w_out.0,
            &mut self.w_out.1,
        ]
    }

    /// Zero every gradient in place (reuse across optimizer steps).
    pub fn clear(&mut self) {
        for v in self.views_mut() {
            for g in v.iter_mut() {
                *g = 0.0;
            }
        }
    }

    /// Scale every gradient (e.g. 1/batch).
    pub fn scale(&mut self, s: f32) {
        for v in self.views_mut() {
            for g in v.iter_mut() {
                *g *= s;
            }
        }
    }

    /// Flatten in the canonical parameter order ([`DrafterModel::flatten`]
    /// uses the same order, so flat Adam applies positionally).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for v in self.views() {
            out.extend_from_slice(v);
        }
        out
    }
}

impl DrafterModel {
    /// Xavier-initialized model.
    pub fn init(rng: &mut Rng) -> Self {
        Self {
            w_in: Linear::init(IN_DIM, D_MODEL, rng),
            ln1: LayerNorm::new(D_MODEL),
            wq: Linear::init(D_MODEL, D_MODEL, rng),
            wk: Linear::init(D_MODEL, D_MODEL, rng),
            wv: Linear::init(D_MODEL, D_MODEL, rng),
            wo: Linear::init(D_MODEL, D_MODEL, rng),
            ln2: LayerNorm::new(D_MODEL),
            w1: Linear::init(D_MODEL, D_FF, rng),
            w2: Linear::init(D_FF, D_MODEL, rng),
            lnf: LayerNorm::new(D_MODEL),
            w_out: Linear::init(D_MODEL, SEG, rng),
        }
    }

    /// Assemble one token's input: `x ‖ time_features(t) ‖ cond`.
    pub fn token_input(x: &[f32], t: usize, cond: &[f32]) -> Vec<f32> {
        debug_assert_eq!(x.len(), SEG);
        debug_assert_eq!(cond.len(), EMBED_DIM);
        let mut input = Vec::with_capacity(IN_DIM);
        input.extend_from_slice(x);
        input.extend_from_slice(&time_features(t));
        input.extend_from_slice(cond);
        input
    }

    /// Forward over a training sequence of `ts.len()` tokens (teacher-
    /// forced latents `xs`, row-major L×SEG; `cond` shared). Returns the
    /// flat L×SEG x̂0 predictions and the cache for [`Self::backward_seq`].
    pub fn forward_seq(&self, xs: &[f32], ts: &[usize], cond: &[f32]) -> (Vec<f32>, SeqCache) {
        let l = ts.len();
        debug_assert_eq!(xs.len(), l * SEG);
        let scale = 1.0 / (D_MODEL as f32).sqrt();
        // Attention reductions go through the same global kernels handle
        // the serving rollouts use, so training-forward == rollout stays
        // bit-identical on whichever path the process runs.
        let kern = Kernels::global();
        let mut cache = SeqCache {
            inputs: Vec::with_capacity(l),
            e: Vec::with_capacity(l),
            n1: Vec::with_capacity(l),
            n1_stats: Vec::with_capacity(l),
            q: Vec::with_capacity(l),
            k: Vec::with_capacity(l),
            v: Vec::with_capacity(l),
            attn: Vec::with_capacity(l),
            ctx: Vec::with_capacity(l),
            h: Vec::with_capacity(l),
            n2: Vec::with_capacity(l),
            n2_stats: Vec::with_capacity(l),
            f1: Vec::with_capacity(l),
            z: Vec::with_capacity(l),
            nf: Vec::with_capacity(l),
            nf_stats: Vec::with_capacity(l),
            y: Vec::with_capacity(l),
        };
        let mut outputs = Vec::with_capacity(l * SEG);
        for j in 0..l {
            let input = Self::token_input(&xs[j * SEG..(j + 1) * SEG], ts[j], cond);
            let mut e = vec![0.0f32; D_MODEL];
            self.w_in.forward(&input, &mut e);
            let mut n1 = vec![0.0f32; D_MODEL];
            let s1 = self.ln1.forward(&e, &mut n1);
            let mut q = vec![0.0f32; D_MODEL];
            self.wq.forward(&n1, &mut q);
            let mut k = vec![0.0f32; D_MODEL];
            self.wk.forward(&n1, &mut k);
            let mut v = vec![0.0f32; D_MODEL];
            self.wv.forward(&n1, &mut v);
            cache.k.push(k);
            cache.v.push(v);

            let mut attn = vec![0.0f32; j + 1];
            for i in 0..=j {
                attn[i] = kern.dot(&q, &cache.k[i]) * scale;
            }
            softmax_inplace(&mut attn);
            let mut ctx = vec![0.0f32; D_MODEL];
            for i in 0..=j {
                kern.add_scaled(&mut ctx, &cache.v[i], attn[i]);
            }
            let mut o = vec![0.0f32; D_MODEL];
            self.wo.forward(&ctx, &mut o);
            let mut h = vec![0.0f32; D_MODEL];
            for i in 0..D_MODEL {
                h[i] = e[i] + o[i];
            }
            let mut n2 = vec![0.0f32; D_MODEL];
            let s2 = self.ln2.forward(&h, &mut n2);
            let mut f1 = vec![0.0f32; D_FF];
            self.w1.forward(&n2, &mut f1);
            for a in f1.iter_mut() {
                *a = a.tanh();
            }
            let mut f2 = vec![0.0f32; D_MODEL];
            self.w2.forward(&f1, &mut f2);
            let mut z = vec![0.0f32; D_MODEL];
            for i in 0..D_MODEL {
                z[i] = h[i] + f2[i];
            }
            let mut nf = vec![0.0f32; D_MODEL];
            let sf = self.lnf.forward(&z, &mut nf);
            let mut y = vec![0.0f32; SEG];
            self.w_out.forward(&nf, &mut y);
            for a in y.iter_mut() {
                *a = a.tanh();
            }

            outputs.extend_from_slice(&y);
            cache.inputs.push(input);
            cache.e.push(e);
            cache.n1.push(n1);
            cache.n1_stats.push(s1);
            cache.q.push(q);
            cache.attn.push(attn);
            cache.ctx.push(ctx);
            cache.h.push(h);
            cache.n2.push(n2);
            cache.n2_stats.push(s2);
            cache.f1.push(f1);
            cache.z.push(z);
            cache.nf.push(nf);
            cache.nf_stats.push(sf);
            cache.y.push(y);
        }
        (outputs, cache)
    }

    /// Backward over a cached sequence: `dys` is dL/dy, flat L×SEG;
    /// parameter gradients accumulate into `grads`.
    pub fn backward_seq(&self, cache: &SeqCache, dys: &[f32], grads: &mut DrafterGrads) {
        let l = cache.y.len();
        debug_assert_eq!(dys.len(), l * SEG);
        let scale = 1.0 / (D_MODEL as f32).sqrt();
        let mut d_e = vec![vec![0.0f32; D_MODEL]; l];
        let mut d_q = vec![vec![0.0f32; D_MODEL]; l];
        let mut d_k = vec![vec![0.0f32; D_MODEL]; l];
        let mut d_v = vec![vec![0.0f32; D_MODEL]; l];

        // Phase A: everything above the attention projections. Cross-token
        // coupling happens only through d_k / d_v, which accumulate here
        // and are folded back in phase B once complete.
        for j in 0..l {
            let dy = &dys[j * SEG..(j + 1) * SEG];
            let mut du = vec![0.0f32; SEG];
            for i in 0..SEG {
                let yv = cache.y[j][i];
                du[i] = dy[i] * (1.0 - yv * yv);
            }
            let mut d_nf = vec![0.0f32; D_MODEL];
            linear_backward(
                &self.w_out,
                &cache.nf[j],
                &du,
                &mut grads.w_out.0,
                &mut grads.w_out.1,
                Some(&mut d_nf),
            );
            let mut d_z = vec![0.0f32; D_MODEL];
            let (mf, rf) = cache.nf_stats[j];
            self.lnf.backward(
                &cache.z[j],
                mf,
                rf,
                &d_nf,
                &mut grads.lnf.0,
                &mut grads.lnf.1,
                &mut d_z,
            );
            // z = h + f2
            let mut d_h = d_z.clone();
            let mut d_f1 = vec![0.0f32; D_FF];
            linear_backward(
                &self.w2,
                &cache.f1[j],
                &d_z,
                &mut grads.w2.0,
                &mut grads.w2.1,
                Some(&mut d_f1),
            );
            let mut d_pre1 = vec![0.0f32; D_FF];
            for i in 0..D_FF {
                let a = cache.f1[j][i];
                d_pre1[i] = d_f1[i] * (1.0 - a * a);
            }
            let mut d_n2 = vec![0.0f32; D_MODEL];
            linear_backward(
                &self.w1,
                &cache.n2[j],
                &d_pre1,
                &mut grads.w1.0,
                &mut grads.w1.1,
                Some(&mut d_n2),
            );
            let (m2, r2) = cache.n2_stats[j];
            self.ln2.backward(
                &cache.h[j],
                m2,
                r2,
                &d_n2,
                &mut grads.ln2.0,
                &mut grads.ln2.1,
                &mut d_h,
            );
            // h = e + o
            for i in 0..D_MODEL {
                d_e[j][i] += d_h[i];
            }
            let mut d_ctx = vec![0.0f32; D_MODEL];
            linear_backward(
                &self.wo,
                &cache.ctx[j],
                &d_h,
                &mut grads.wo.0,
                &mut grads.wo.1,
                Some(&mut d_ctx),
            );
            // Attention row j: ctx_j = Σ_i a_{ji} v_i over i ≤ j.
            let a = &cache.attn[j];
            let mut d_a = vec![0.0f32; j + 1];
            for i in 0..=j {
                d_a[i] = dot(&cache.v[i], &d_ctx);
                add_scaled(&mut d_v[i], &d_ctx, a[i]);
            }
            let sum_da_a: f32 = (0..=j).map(|i| d_a[i] * a[i]).sum();
            for i in 0..=j {
                let d_score = a[i] * (d_a[i] - sum_da_a) * scale;
                add_scaled(&mut d_q[j], &cache.k[i], d_score);
                add_scaled(&mut d_k[i], &cache.q[j], d_score);
            }
        }

        // Phase B: fold the completed q/k/v grads through the projections,
        // the pre-attention LayerNorm, and the token embedding.
        for j in 0..l {
            let mut d_n1 = vec![0.0f32; D_MODEL];
            linear_backward(
                &self.wq,
                &cache.n1[j],
                &d_q[j],
                &mut grads.wq.0,
                &mut grads.wq.1,
                Some(&mut d_n1),
            );
            linear_backward(
                &self.wk,
                &cache.n1[j],
                &d_k[j],
                &mut grads.wk.0,
                &mut grads.wk.1,
                Some(&mut d_n1),
            );
            linear_backward(
                &self.wv,
                &cache.n1[j],
                &d_v[j],
                &mut grads.wv.0,
                &mut grads.wv.1,
                Some(&mut d_n1),
            );
            let (m1, r1) = cache.n1_stats[j];
            self.ln1.backward(
                &cache.e[j],
                m1,
                r1,
                &d_n1,
                &mut grads.ln1.0,
                &mut grads.ln1.1,
                &mut d_e[j],
            );
            linear_backward(
                &self.w_in,
                &cache.inputs[j],
                &d_e[j],
                &mut grads.w_in.0,
                &mut grads.w_in.1,
                None,
            );
        }
    }

    /// Single-step x̂0 prediction with no rollout context (sequence
    /// length 1) — what `drafter_step` serves. Convenience wrapper that
    /// builds a throwaway f32 [`crate::drafter::ServingDrafter`] on the
    /// global kernel path; hot paths hold a `ServingDrafter` instead.
    pub fn infer_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Vec<f32> {
        let serving = crate::drafter::serving::ServingDrafter::from_model(self, Kernels::global());
        let mut roll = serving.start_rollout();
        roll.push(x, t, cond)
    }

    fn flat_views(&self) -> [&[f32]; 22] {
        [
            &self.w_in.w,
            &self.w_in.b,
            &self.ln1.gamma,
            &self.ln1.beta,
            &self.wq.w,
            &self.wq.b,
            &self.wk.w,
            &self.wk.b,
            &self.wv.w,
            &self.wv.b,
            &self.wo.w,
            &self.wo.b,
            &self.ln2.gamma,
            &self.ln2.beta,
            &self.w1.w,
            &self.w1.b,
            &self.w2.w,
            &self.w2.b,
            &self.lnf.gamma,
            &self.lnf.beta,
            &self.w_out.w,
            &self.w_out.b,
        ]
    }

    fn flat_views_mut(&mut self) -> [&mut Vec<f32>; 22] {
        [
            &mut self.w_in.w,
            &mut self.w_in.b,
            &mut self.ln1.gamma,
            &mut self.ln1.beta,
            &mut self.wq.w,
            &mut self.wq.b,
            &mut self.wk.w,
            &mut self.wk.b,
            &mut self.wv.w,
            &mut self.wv.b,
            &mut self.wo.w,
            &mut self.wo.b,
            &mut self.ln2.gamma,
            &mut self.ln2.beta,
            &mut self.w1.w,
            &mut self.w1.b,
            &mut self.w2.w,
            &mut self.w2.b,
            &mut self.lnf.gamma,
            &mut self.lnf.beta,
            &mut self.w_out.w,
            &mut self.w_out.b,
        ]
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.flat_views().iter().map(|v| v.len()).sum()
    }

    /// Flatten all parameters in the canonical order shared with
    /// [`DrafterGrads::flatten`].
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_params());
        for v in self.flat_views() {
            out.extend_from_slice(v);
        }
        out
    }

    /// Load parameters from a flat vector (canonical order).
    pub fn unflatten(&mut self, flat: &[f32]) {
        let mut i = 0;
        for v in self.flat_views_mut() {
            let n = v.len();
            v.copy_from_slice(&flat[i..i + n]);
            i += n;
        }
        assert_eq!(i, flat.len(), "flat drafter parameter size mismatch");
    }

    /// Serialize to a checkpoint (architecture dims + flat weights).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::Str(CHECKPOINT_FORMAT.into())),
            ("d_model", Json::Num(D_MODEL as f64)),
            ("d_ff", Json::Num(D_FF as f64)),
            ("time_feats", Json::Num(TIME_FEATS as f64)),
            ("seg", Json::Num(SEG as f64)),
            ("embed_dim", Json::Num(EMBED_DIM as f64)),
            ("diffusion_steps", Json::Num(DIFFUSION_STEPS as f64)),
            ("params", Json::nums(self.flatten().into_iter().map(|x| x as f64))),
        ])
    }

    /// Deserialize, cross-checking every architecture dimension against
    /// this build's constants so a drifted checkpoint fails loudly
    /// instead of mis-executing (same policy as `runtime::artifact`).
    pub fn from_json(v: &Json) -> Result<Self> {
        let format = v.get("format")?.as_str()?.to_string();
        ensure!(
            format == CHECKPOINT_FORMAT,
            "drafter checkpoint format '{format}' != '{CHECKPOINT_FORMAT}'"
        );
        for (key, want) in [
            ("d_model", D_MODEL),
            ("d_ff", D_FF),
            ("time_feats", TIME_FEATS),
            ("seg", SEG),
            ("embed_dim", EMBED_DIM),
            ("diffusion_steps", DIFFUSION_STEPS),
        ] {
            let got = v.get(key)?.as_usize()?;
            ensure!(got == want, "drafter checkpoint {key}={got}, this build wants {want}");
        }
        let params = v.get("params")?.as_f32_vec()?;
        let mut model = DrafterModel::init(&mut Rng::seed_from_u64(0));
        ensure!(
            params.len() == model.n_params(),
            "drafter checkpoint has {} params, model wants {}",
            params.len(),
            model.n_params()
        );
        model.unflatten(&params);
        Ok(model)
    }

    /// Save to a JSON checkpoint file.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json().save(path)
    }

    /// Load from a JSON checkpoint file.
    pub fn load(path: &Path) -> Result<Self> {
        Self::from_json(&Json::load(path)?)
            .with_context(|| format!("loading drafter checkpoint {}", path.display()))
    }
}

/// Convert an x̂0 prediction into the ε the [`crate::policy::Denoiser`]
/// contract expects: ε = (x_t − √ᾱ_t·x̂0)/√(1−ᾱ_t). Exactly inverts the
/// schedule's `predict_x0` for |x̂0| ≤ 1 (which tanh guarantees), so the
/// engine's accept test sees the model's x̂0 unchanged.
pub fn eps_from_x0(sched: &DdpmSchedule, t: usize, x: &[f32], x0: &[f32], out: &mut [f32]) {
    let ab = sched.alpha_bars[t];
    let sa = ab.sqrt();
    let sb = (1.0 - ab).sqrt().max(1e-4);
    for i in 0..x.len() {
        out[i] = (x[i] - sa * x0[i]) / sb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::TempDir;

    fn small_inputs(l: usize, seed: u64) -> (Vec<f32>, Vec<usize>, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let xs = rng.normal_vec(l * SEG);
        let ts: Vec<usize> = (0..l).map(|j| 60 - j).collect();
        let cond = rng.normal_vec(EMBED_DIM);
        (xs, ts, cond)
    }

    #[test]
    fn infer_step_is_the_context_free_first_token() {
        let mut rng = Rng::seed_from_u64(2);
        let model = DrafterModel::init(&mut rng);
        let (xs, ts, cond) = small_inputs(1, 3);
        let (seq_out, _) = model.forward_seq(&xs, &ts, &cond);
        assert_eq!(model.infer_step(&xs, ts[0], &cond), seq_out);
    }

    #[test]
    fn outputs_are_tanh_bounded() {
        let mut rng = Rng::seed_from_u64(4);
        let model = DrafterModel::init(&mut rng);
        let (xs, ts, cond) = small_inputs(3, 5);
        let (out, _) = model.forward_seq(&xs, &ts, &cond);
        for v in &out {
            assert!(v.is_finite() && v.abs() <= 1.0);
        }
    }

    /// The heart of the substrate: analytic gradients of the full
    /// attention block against central finite differences, for
    /// parameters in every layer.
    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Rng::seed_from_u64(6);
        let mut model = DrafterModel::init(&mut rng);
        let (xs, ts, cond) = small_inputs(3, 7);
        // Loss = Σ_j Σ_i coef_{j,i} · y_{j,i} for fixed pseudo-random coef.
        let coef: Vec<f32> = rng.normal_vec(3 * SEG);
        let loss = |m: &DrafterModel| -> f64 {
            let (out, _) = m.forward_seq(&xs, &ts, &cond);
            out.iter().zip(&coef).map(|(a, b)| (*a as f64) * (*b as f64)).sum()
        };
        let (_, cache) = model.forward_seq(&xs, &ts, &cond);
        let mut grads = DrafterGrads::zeros(&model);
        model.backward_seq(&cache, &coef, &mut grads);
        let eps = 2e-3f32;
        // (param accessor, grad accessor, probe index) across all layers.
        type P = (fn(&mut DrafterModel) -> &mut Vec<f32>, fn(&DrafterGrads) -> &Vec<f32>, usize);
        let probes: Vec<P> = vec![
            (|m| &mut m.w_in.w, |g| &g.w_in.0, 40),
            (|m| &mut m.w_in.b, |g| &g.w_in.1, 3),
            (|m| &mut m.ln1.gamma, |g| &g.ln1.0, 5),
            (|m| &mut m.wq.w, |g| &g.wq.0, 17),
            (|m| &mut m.wk.w, |g| &g.wk.0, 33),
            (|m| &mut m.wv.w, |g| &g.wv.0, 51),
            (|m| &mut m.wo.w, |g| &g.wo.0, 9),
            (|m| &mut m.ln2.beta, |g| &g.ln2.1, 2),
            (|m| &mut m.w1.w, |g| &g.w1.0, 70),
            (|m| &mut m.w2.w, |g| &g.w2.0, 44),
            (|m| &mut m.lnf.gamma, |g| &g.lnf.0, 11),
            (|m| &mut m.w_out.w, |g| &g.w_out.0, 200),
            (|m| &mut m.w_out.b, |g| &g.w_out.1, 30),
        ];
        for (pi, (param, grad, idx)) in probes.iter().enumerate() {
            let orig = param(&mut model)[*idx];
            param(&mut model)[*idx] = orig + eps;
            let lp = loss(&model);
            param(&mut model)[*idx] = orig - eps;
            let lm = loss(&model);
            param(&mut model)[*idx] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let an = grad(&grads)[*idx];
            assert!(
                (fd - an).abs() < 3e-2 * fd.abs().max(an.abs()).max(0.1),
                "probe {pi} idx {idx}: fd {fd} vs analytic {an}"
            );
        }
    }

    #[test]
    fn flatten_roundtrip_preserves_outputs() {
        let mut rng = Rng::seed_from_u64(8);
        let model = DrafterModel::init(&mut rng);
        let flat = model.flatten();
        assert_eq!(flat.len(), model.n_params());
        let mut other = DrafterModel::init(&mut rng); // different init
        other.unflatten(&flat);
        let (xs, ts, cond) = small_inputs(2, 9);
        assert_eq!(
            model.forward_seq(&xs, &ts, &cond).0,
            other.forward_seq(&xs, &ts, &cond).0
        );
    }

    #[test]
    fn grads_flatten_matches_model_order() {
        let mut rng = Rng::seed_from_u64(10);
        let model = DrafterModel::init(&mut rng);
        let grads = DrafterGrads::zeros(&model);
        let gv = grads.views();
        let mv = model.flat_views();
        assert_eq!(gv.len(), mv.len());
        for (g, m) in gv.iter().zip(mv.iter()) {
            assert_eq!(g.len(), m.len(), "grad/param shape drift");
        }
        assert_eq!(grads.flatten().len(), model.n_params());
    }

    #[test]
    fn checkpoint_roundtrips_bitwise() {
        let mut rng = Rng::seed_from_u64(12);
        let model = DrafterModel::init(&mut rng);
        let dir = TempDir::new("drafter_ckpt");
        let path = dir.path().join("drafter.json");
        model.save(&path).unwrap();
        let loaded = DrafterModel::load(&path).unwrap();
        let (xs, ts, cond) = small_inputs(4, 13);
        assert_eq!(
            model.forward_seq(&xs, &ts, &cond).0,
            loaded.forward_seq(&xs, &ts, &cond).0,
            "JSON roundtrip must preserve every bit"
        );
    }

    #[test]
    fn checkpoint_dim_drift_fails_loudly() {
        let mut rng = Rng::seed_from_u64(14);
        let model = DrafterModel::init(&mut rng);
        let mut j = model.to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("d_model".into(), Json::Num((D_MODEL + 1) as f64));
        }
        let err = DrafterModel::from_json(&j).unwrap_err();
        assert!(err.to_string().contains("d_model"), "{err:#}");
        let mut j2 = model.to_json();
        if let Json::Obj(m) = &mut j2 {
            m.insert("format".into(), Json::Str("bogus".into()));
        }
        assert!(DrafterModel::from_json(&j2).is_err());
    }

    #[test]
    fn eps_from_x0_inverts_predict_x0() {
        let sched = DdpmSchedule::cosine(DIFFUSION_STEPS);
        let mut rng = Rng::seed_from_u64(16);
        let x = rng.normal_vec(SEG);
        let x0: Vec<f32> = rng.normal_vec(SEG).iter().map(|v| v.tanh()).collect();
        for t in [1usize, 10, 50, 99] {
            let mut eps = vec![0.0; SEG];
            eps_from_x0(&sched, t, &x, &x0, &mut eps);
            let mut rec = vec![0.0; SEG];
            sched.predict_x0(t, &x, &eps, &mut rec);
            for i in 0..SEG {
                assert!(
                    (rec[i] - x0[i]).abs() < 1e-3,
                    "t={t} i={i}: {} vs {}",
                    rec[i],
                    x0[i]
                );
            }
        }
    }
}
