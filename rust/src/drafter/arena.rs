//! Shared drafter KV arena: slab/paged block storage for the
//! wave-stepped batched rollout path (`drafter_rollout_many`).
//!
//! [`KvArena`] owns fixed-size KV blocks ([`BLOCK_TOKENS`] tokens ×
//! `width` floats of K and of V) handed out from a free list to
//! per-session **chains**. A chain lives for one speculative round —
//! the drafter's causal context is round-local — and releasing it
//! returns every block to the free list, so steady-state serving
//! allocates nothing: capacity converges to the high-water mark of
//! concurrent demand and is reused forever after. Chains are addressed
//! by copyable [`ChainId`] handles (mistral.rs-style paged KV, scaled
//! to this crate's one-block drafter).
//!
//! Attention only ever reads rows of one session's own chain, so
//! arena-backed rollouts are bit-identical to rollouts over private
//! per-session buffers — the arena moves allocations and locality,
//! never bits (pinned by the property tests below and the wave-vs-
//! serial suites in `drafter::model` / `drafter::backend`).
//!
//! Round-locality is also what makes elastic-fleet session migration
//! cheap: because every chain is released at the end of its speculative
//! round, a session that moves shards at a request boundary leaves
//! **nothing** behind in the source shard's arena and needs nothing
//! pre-warmed in the destination's — the arena is deliberately absent
//! from `SessionSnapshot` (see [`crate::coordinator::fleet`]).

/// Tokens per KV block. Small enough that a k = 1 round strands at
/// most 3 slots; large enough that a K_MAX = 16 round chains only 4
/// blocks.
pub const BLOCK_TOKENS: usize = 4;

/// Handle to one session's KV chain (valid until [`KvArena::release`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChainId(usize);

/// One fixed-size slab of K and V rows.
#[derive(Debug)]
struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
}

/// Per-session block chain: the ordered blocks holding its KV rows.
#[derive(Debug)]
struct Chain {
    blocks: Vec<usize>,
    len: usize,
    live: bool,
}

/// Slab allocator for drafter KV rows: free-listed fixed-size blocks,
/// per-session chains, drop-on-round-end reclamation.
#[derive(Debug)]
pub struct KvArena {
    /// Floats per K row (= per V row).
    width: usize,
    blocks: Vec<Block>,
    free_blocks: Vec<usize>,
    chains: Vec<Chain>,
    free_chains: Vec<usize>,
    in_use: usize,
    high_water: usize,
}

impl KvArena {
    /// Empty arena for `width`-float KV rows. No blocks are allocated
    /// until a chain pushes rows.
    pub fn new(width: usize) -> Self {
        assert!(width > 0, "KV row width must be positive");
        Self {
            width,
            blocks: Vec::new(),
            free_blocks: Vec::new(),
            chains: Vec::new(),
            free_chains: Vec::new(),
            in_use: 0,
            high_water: 0,
        }
    }

    /// Floats per KV row.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Open a fresh (empty) chain, reusing a released chain slot when
    /// one is free.
    pub fn new_chain(&mut self) -> ChainId {
        match self.free_chains.pop() {
            Some(id) => {
                debug_assert!(!self.chains[id].live && self.chains[id].blocks.is_empty());
                self.chains[id].live = true;
                ChainId(id)
            }
            None => {
                self.chains.push(Chain { blocks: Vec::new(), len: 0, live: true });
                ChainId(self.chains.len() - 1)
            }
        }
    }

    /// Rows pushed into `chain` so far.
    pub fn chain_len(&self, chain: ChainId) -> usize {
        let c = &self.chains[chain.0];
        debug_assert!(c.live, "chain_len of a released chain");
        c.len
    }

    /// Append one KV row to `chain`, growing it by a block when the
    /// last block is full (free list first, fresh allocation only past
    /// the arena's high-water mark).
    pub fn push_kv(&mut self, chain: ChainId, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.width);
        debug_assert_eq!(v.len(), self.width);
        assert!(self.chains[chain.0].live, "push_kv into a released chain");
        let len = self.chains[chain.0].len;
        if len % BLOCK_TOKENS == 0 {
            let b = match self.free_blocks.pop() {
                Some(b) => b,
                None => {
                    self.blocks.push(Block {
                        k: vec![0.0; BLOCK_TOKENS * self.width],
                        v: vec![0.0; BLOCK_TOKENS * self.width],
                    });
                    self.blocks.len() - 1
                }
            };
            self.chains[chain.0].blocks.push(b);
            self.in_use += 1;
            self.high_water = self.high_water.max(self.in_use);
        }
        let b = *self.chains[chain.0].blocks.last().expect("block ensured above");
        let at = (len % BLOCK_TOKENS) * self.width;
        self.blocks[b].k[at..at + self.width].copy_from_slice(k);
        self.blocks[b].v[at..at + self.width].copy_from_slice(v);
        self.chains[chain.0].len = len + 1;
    }

    /// K row `i` of `chain` (0-based push order).
    pub fn k_row(&self, chain: ChainId, i: usize) -> &[f32] {
        let c = &self.chains[chain.0];
        debug_assert!(c.live && i < c.len, "k_row({i}) of len-{} chain", c.len);
        let at = (i % BLOCK_TOKENS) * self.width;
        &self.blocks[c.blocks[i / BLOCK_TOKENS]].k[at..at + self.width]
    }

    /// V row `i` of `chain` (0-based push order).
    pub fn v_row(&self, chain: ChainId, i: usize) -> &[f32] {
        let c = &self.chains[chain.0];
        debug_assert!(c.live && i < c.len, "v_row({i}) of len-{} chain", c.len);
        let at = (i % BLOCK_TOKENS) * self.width;
        &self.blocks[c.blocks[i / BLOCK_TOKENS]].v[at..at + self.width]
    }

    /// Close `chain`: every block returns to the free list and the
    /// handle becomes invalid (round-end reclamation).
    pub fn release(&mut self, chain: ChainId) {
        assert!(self.chains[chain.0].live, "release of a dead chain");
        let blocks = std::mem::take(&mut self.chains[chain.0].blocks);
        self.chains[chain.0].len = 0;
        self.chains[chain.0].live = false;
        self.in_use -= blocks.len();
        self.free_blocks.extend(blocks);
        self.free_chains.push(chain.0);
    }

    /// Blocks currently held by live chains.
    pub fn blocks_in_use(&self) -> usize {
        self.in_use
    }

    /// Peak concurrent block demand over the arena's lifetime (the
    /// metrics gauge; also exactly the number of blocks ever allocated,
    /// since a block is only created when the free list is empty).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    /// Total blocks backing the arena (free + in use).
    pub fn capacity_blocks(&self) -> usize {
        self.blocks.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::check_property;

    #[test]
    fn rows_round_trip_bitwise() {
        let mut arena = KvArena::new(8);
        let a = arena.new_chain();
        let b = arena.new_chain();
        let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..10)
            .map(|i| {
                let k: Vec<f32> = (0..8).map(|j| (i * 8 + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                (k, v)
            })
            .collect();
        // Interleave pushes so the two chains' blocks interleave in the
        // slab — reads must still come back per-chain, in push order.
        for (i, (k, v)) in rows.iter().enumerate() {
            let chain = if i % 2 == 0 { a } else { b };
            arena.push_kv(chain, k, v);
        }
        assert_eq!(arena.chain_len(a), 5);
        assert_eq!(arena.chain_len(b), 5);
        for (i, (k, v)) in rows.iter().enumerate() {
            let (chain, at) = if i % 2 == 0 { (a, i / 2) } else { (b, i / 2) };
            assert_eq!(arena.k_row(chain, at), &k[..], "k row {i}");
            assert_eq!(arena.v_row(chain, at), &v[..], "v row {i}");
        }
    }

    #[test]
    fn chains_grow_block_granular() {
        let mut arena = KvArena::new(4);
        let c = arena.new_chain();
        for len in 1..=(3 * BLOCK_TOKENS) {
            arena.push_kv(c, &[len as f32; 4], &[0.0; 4]);
            let want = len.div_ceil(BLOCK_TOKENS);
            assert_eq!(arena.blocks_in_use(), want, "len {len}");
        }
        arena.release(c);
        assert_eq!(arena.blocks_in_use(), 0);
        assert_eq!(arena.high_water(), 3);
    }

    #[test]
    fn released_blocks_are_reused_not_reallocated() {
        let mut arena = KvArena::new(4);
        for round in 0..5 {
            let c = arena.new_chain();
            for _ in 0..16 {
                arena.push_kv(c, &[round as f32; 4], &[0.0; 4]);
            }
            arena.release(c);
        }
        // 16 tokens = 4 blocks per round; rounds reuse them, so capacity
        // and high-water both stay at the single-round demand.
        assert_eq!(arena.high_water(), 4);
        assert_eq!(arena.capacity_blocks(), 4);
        assert_eq!(arena.blocks_in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "released chain")]
    fn pushing_into_a_released_chain_panics() {
        let mut arena = KvArena::new(2);
        let c = arena.new_chain();
        arena.release(c);
        arena.push_kv(c, &[0.0; 2], &[0.0; 2]);
    }

    /// Satellite acceptance: after N random session lifecycles no block
    /// leaks, the bookkeeping matches an independent model at every
    /// step, and the high-water mark is bounded by the peak modelled
    /// demand (capacity never exceeds it either — blocks are only
    /// minted when the free list runs dry).
    #[test]
    fn random_lifecycles_leak_nothing_and_bound_high_water() {
        check_property("kv_arena_lifecycles", 50, |rng| {
            let mut arena = KvArena::new(3);
            // Model: (chain, tokens pushed) for every live chain.
            let mut live: Vec<(ChainId, usize)> = Vec::new();
            let mut peak_demand = 0usize;
            for _ in 0..rng.below(200) + 20 {
                match rng.below(4) {
                    // Open a chain (bounded fleet).
                    0 if live.len() < 12 => live.push((arena.new_chain(), 0)),
                    // Push a row into a random live chain.
                    1 | 2 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        arena.push_kv(live[i].0, &[1.0; 3], &[2.0; 3]);
                        live[i].1 += 1;
                        assert_eq!(arena.chain_len(live[i].0), live[i].1);
                    }
                    // Release a random live chain (mid-wave leave).
                    3 if !live.is_empty() => {
                        let i = rng.below(live.len());
                        arena.release(live.swap_remove(i).0);
                    }
                    _ => {}
                }
                let demand: usize =
                    live.iter().map(|&(_, n)| n.div_ceil(BLOCK_TOKENS)).sum();
                assert_eq!(arena.blocks_in_use(), demand, "bookkeeping drift");
                peak_demand = peak_demand.max(demand);
            }
            for (c, _) in live.drain(..) {
                arena.release(c);
            }
            assert_eq!(arena.blocks_in_use(), 0, "blocks leaked");
            assert_eq!(arena.high_water(), peak_demand, "high-water drift");
            assert_eq!(
                arena.capacity_blocks(),
                peak_demand,
                "arena over-allocated beyond peak demand"
            );
        });
    }
}
