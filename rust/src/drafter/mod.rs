//! The native distilled-drafter subsystem (paper §3.1, first pillar:
//! "distill a Transformer-based drafter to imitate the base model and
//! replace its costly denoising calls").
//!
//! Before this subsystem the crate could only *consume* drafters (the
//! mock's analytic pair, or opaque AOT artifacts); it can now *produce*
//! them in-crate and swap them at serve time:
//!
//! ```text
//! train-time                                serve-time
//! ----------                                ----------
//! base Denoiser ──roll env fleet──▶ (x_t, t, cond, ε_target) tuples
//!        │                              │  (stored as target x̂0)
//!        │                              ▼
//!        │                  train::distill — MSE + K-step
//!        │                  rollout-consistency windows
//!        │                              │
//!        │                              ▼
//!        │                  model::DrafterModel ──save/load──▶ v1 JSON
//!        │                  (1-block causal Transformer        checkpoint
//!        │                   over denoising-step tokens)           │
//!        │                              │          ts-dp quantize-drafter
//!        │                              ▼                          ▼
//!        │                  serving::ServingDrafter ◀──────── int8 v2 JSON
//!        │                  (inference-only: kernels-layer      checkpoint
//!        │                   dispatch, f32 or int8 per-channel
//!        │                   weights; owns RolloutState serial
//!        │                   + WaveRollout batched decoding)
//!        ▼                              ▼
//! backend::DistilledDrafter  ◀── serve --drafter PATH [--drafter-dtype]
//!   · target_* / encode delegate to base (losslessness untouched)
//!   · drafter_step / natively fused drafter_rollout via
//!     serving::RolloutState (Some for every k, KV-cached causal
//!     decode, k/8 NFE)
//!   · drafter_rollout_many: continuous batching at draft-step
//!     granularity — every in-flight draft advances one wave per step
//!     over a shared per-shard KV arena (arena::KvArena), projections
//!     executed as blocked batched GEMVs, bit-identical to per-request
//!     rollouts on every kernel path and either dtype
//! ```
//!
//! `ts-dp distill-drafter` drives the pipeline from the CLI and `ts-dp
//! quantize-drafter` converts a v1 checkpoint to int8; the serving fleet
//! (`serve --drafter`), the open-loop harness (`load-sweep --drafter`)
//! and the episode evaluator (`episode --drafter`) all wrap their
//! replicas through [`DistilledDrafter`], and
//! [`crate::coordinator::workload::DrafterKind`] labels the swap (and
//! its dtype) in session specs and metrics summaries.

pub mod arena;
pub mod backend;
pub mod cli;
pub mod layers;
pub mod model;
pub mod serving;
pub mod train;

pub use arena::{ChainId, KvArena};
pub use backend::DistilledDrafter;
pub use model::DrafterModel;
pub use serving::{DrafterCheckpoint, DrafterDtype, ServingDrafter};
pub use train::{
    accept_scorecard, accept_stats, collect_trajectories, distill, train_on, DistillConfig,
};
