//! Serve-time drafter execution: kernel-dispatched rollouts over f32 or
//! int8 per-channel quantized weights.
//!
//! Training owns [`DrafterModel`] (f32 weights + backprop); serving owns
//! [`ServingDrafter`] — an inference-only view that pins a
//! [`Kernels`] handle and stores each projection as either the f32
//! matrix or its int8 per-output-channel quantization
//! ([`crate::kernels::QuantizedLinear`]). Both rollout forms live here:
//!
//! * [`RolloutState`] — serial KV-cached causal decoding, one session.
//! * [`WaveRollout`] — continuous-batched decoding: every in-flight
//!   session advances one denoising-step token per wave, KV rows in a
//!   shared per-shard [`KvArena`], with the wave's projections executed
//!   as **blocked batched GEMVs** ([`Kernels::gemv_rows`]) so each
//!   weight row streams through cache once per wave instead of once per
//!   session.
//!
//! Determinism contract (unchanged from the pre-kernels code): per-row
//! arithmetic and arithmetic order are identical between the two forms —
//! batched GEMV is bitwise equal to per-row GEMV by construction — so a
//! wave-stepped rollout is **bit-identical** to the serial per-request
//! rollout on every kernel path and either dtype, no matter which
//! sessions share its waves. The tests below pin serial == wave for f32
//! and int8, and serial == `forward_seq` (training forward) for f32.
//!
//! Quantized checkpoints are a distinct JSON format
//! ([`CHECKPOINT_FORMAT_INT8`], "v2"): int8 weights + per-channel scales
//! + f32 biases/LayerNorms, produced by `ts-dp quantize-drafter` (or
//! in-situ from a v1 checkpoint at load). Quantizing only the drafter
//! keeps served actions lossless — the target still verifies every
//! draft; only the accept rate (the speedup) is at stake, and that is
//! gated by accept-parity tests and the bench suite.

use crate::config::{ACT_DIM, DIFFUSION_STEPS, EMBED_DIM, HORIZON};
use crate::drafter::arena::{ChainId, KvArena};
use crate::drafter::layers::{softmax_inplace, time_features, LayerNorm, TIME_FEATS};
use crate::drafter::model::{DrafterModel, D_FF, D_MODEL, IN_DIM};
use crate::kernels::{Kernels, QuantizedLinear};
use crate::scheduler::nn::Linear;
use crate::util::json::Json;
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;

/// Flattened segment size (one token's latent).
const SEG: usize = HORIZON * ACT_DIM;

/// Checkpoint format tag for int8 per-channel quantized drafters.
pub const CHECKPOINT_FORMAT_INT8: &str = "ts-dp-drafter-int8-v2";

/// Weight storage dtype of a serving drafter.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DrafterDtype {
    /// Full-precision f32 weights (bit-exact with training).
    F32,
    /// Int8 per-output-channel quantized weights, f32 accumulate.
    Int8,
}

impl DrafterDtype {
    /// Stable label (`f32` / `int8`) for metrics and CLI round-trips.
    pub fn name(&self) -> &'static str {
        match self {
            DrafterDtype::F32 => "f32",
            DrafterDtype::Int8 => "int8",
        }
    }

    /// Parse a `--drafter-dtype` flag value.
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DrafterDtype::F32),
            "int8" => Ok(DrafterDtype::Int8),
            other => bail!("unknown drafter dtype '{other}' (expected f32|int8)"),
        }
    }
}

/// One projection of the serving drafter: f32 or int8 storage, same
/// GEMV contract either way.
#[derive(Debug, Clone)]
enum Proj {
    F32(Linear),
    Int8(QuantizedLinear),
}

impl Proj {
    fn forward(&self, kern: Kernels, x: &[f32], y: &mut [f32]) {
        match self {
            Proj::F32(l) => kern.gemv(&l.w, &l.b, l.in_dim, l.out_dim, x, y),
            Proj::Int8(q) => q.forward(kern, x, y),
        }
    }

    fn forward_rows(&self, kern: Kernels, xs: &[f32], ys: &mut [f32]) {
        match self {
            Proj::F32(l) => kern.gemv_rows(&l.w, &l.b, l.in_dim, l.out_dim, xs, ys),
            Proj::Int8(q) => q.forward_rows(kern, xs, ys),
        }
    }
}

/// Inference-only drafter: the [`DrafterModel`] architecture with a
/// pinned kernel path and per-projection f32/int8 storage. Cheap to
/// clone relative to serving traffic (one copy per shard), and the only
/// type the rollout paths touch — training never sees it.
#[derive(Debug, Clone)]
pub struct ServingDrafter {
    kern: Kernels,
    w_in: Proj,
    ln1: LayerNorm,
    wq: Proj,
    wk: Proj,
    wv: Proj,
    wo: Proj,
    ln2: LayerNorm,
    w1: Proj,
    w2: Proj,
    lnf: LayerNorm,
    w_out: Proj,
}

/// `(name, in_dim, out_dim)` of every projection in canonical
/// (checkpoint) order.
const PROJ_DIMS: [(&str, usize, usize); 8] = [
    ("w_in", IN_DIM, D_MODEL),
    ("wq", D_MODEL, D_MODEL),
    ("wk", D_MODEL, D_MODEL),
    ("wv", D_MODEL, D_MODEL),
    ("wo", D_MODEL, D_MODEL),
    ("w1", D_MODEL, D_FF),
    ("w2", D_FF, D_MODEL),
    ("w_out", D_MODEL, SEG),
];

impl ServingDrafter {
    /// Full-precision serving view of a trained model: projections are
    /// cloned f32 weights, arithmetic is bit-exact with `m`'s own
    /// forward on the same kernel path.
    pub fn from_model(m: &DrafterModel, kern: Kernels) -> Self {
        Self {
            kern,
            w_in: Proj::F32(m.w_in.clone()),
            ln1: m.ln1.clone(),
            wq: Proj::F32(m.wq.clone()),
            wk: Proj::F32(m.wk.clone()),
            wv: Proj::F32(m.wv.clone()),
            wo: Proj::F32(m.wo.clone()),
            ln2: m.ln2.clone(),
            w1: Proj::F32(m.w1.clone()),
            w2: Proj::F32(m.w2.clone()),
            lnf: m.lnf.clone(),
            w_out: Proj::F32(m.w_out.clone()),
        }
    }

    /// Int8 per-output-channel quantization of a trained model: every
    /// projection absmax-quantized per output row; biases and LayerNorms
    /// stay f32 (they're O(width), the matrices are O(width²)).
    pub fn quantize(m: &DrafterModel, kern: Kernels) -> Self {
        let q = |l: &Linear| Proj::Int8(QuantizedLinear::quantize(&l.w, &l.b, l.in_dim, l.out_dim));
        Self {
            kern,
            w_in: q(&m.w_in),
            ln1: m.ln1.clone(),
            wq: q(&m.wq),
            wk: q(&m.wk),
            wv: q(&m.wv),
            wo: q(&m.wo),
            ln2: m.ln2.clone(),
            w1: q(&m.w1),
            w2: q(&m.w2),
            lnf: m.lnf.clone(),
            w_out: q(&m.w_out),
        }
    }

    /// Weight dtype (uniform across projections by construction).
    pub fn dtype(&self) -> DrafterDtype {
        match self.w_in {
            Proj::F32(_) => DrafterDtype::F32,
            Proj::Int8(_) => DrafterDtype::Int8,
        }
    }

    /// The kernel handle every rollout through this drafter uses.
    pub fn kernels(&self) -> Kernels {
        self.kern
    }

    /// Start a serial KV-cached rollout.
    pub fn start_rollout(&self) -> RolloutState<'_> {
        RolloutState { d: self, ks: Vec::new(), vs: Vec::new() }
    }

    fn projs(&self) -> [&Proj; 8] {
        [&self.w_in, &self.wq, &self.wk, &self.wv, &self.wo, &self.w1, &self.w2, &self.w_out]
    }

    /// Serialize an int8 drafter to the v2 checkpoint format. Errors on
    /// an f32 drafter — full-precision checkpoints are the v1 format
    /// owned by [`DrafterModel`].
    pub fn to_json(&self) -> Result<Json> {
        ensure!(
            self.dtype() == DrafterDtype::Int8,
            "only int8 drafters serialize as {CHECKPOINT_FORMAT_INT8}; save f32 models via DrafterModel"
        );
        let mut q_all: Vec<f64> = Vec::new();
        let mut scales_all: Vec<f64> = Vec::new();
        let mut biases_all: Vec<f64> = Vec::new();
        for p in self.projs() {
            let Proj::Int8(ql) = p else { unreachable!("dtype checked above") };
            q_all.extend(ql.q.iter().map(|&v| v as f64));
            scales_all.extend(ql.scales.iter().map(|&v| v as f64));
            biases_all.extend(ql.b.iter().map(|&v| v as f64));
        }
        let mut ln_all: Vec<f64> = Vec::new();
        for ln in [&self.ln1, &self.ln2, &self.lnf] {
            ln_all.extend(ln.gamma.iter().map(|&v| v as f64));
            ln_all.extend(ln.beta.iter().map(|&v| v as f64));
        }
        Ok(Json::obj(vec![
            ("format", Json::Str(CHECKPOINT_FORMAT_INT8.into())),
            ("d_model", Json::Num(D_MODEL as f64)),
            ("d_ff", Json::Num(D_FF as f64)),
            ("time_feats", Json::Num(TIME_FEATS as f64)),
            ("seg", Json::Num(SEG as f64)),
            ("embed_dim", Json::Num(EMBED_DIM as f64)),
            ("diffusion_steps", Json::Num(DIFFUSION_STEPS as f64)),
            ("q", Json::nums(q_all)),
            ("scales", Json::nums(scales_all)),
            ("biases", Json::nums(biases_all)),
            ("ln", Json::nums(ln_all)),
        ]))
    }

    /// Deserialize a v2 int8 checkpoint, cross-checking the format tag
    /// and every architecture dimension (same fail-loudly policy as the
    /// v1 loader).
    pub fn from_json(v: &Json, kern: Kernels) -> Result<Self> {
        let format = v.get("format")?.as_str()?.to_string();
        ensure!(
            format == CHECKPOINT_FORMAT_INT8,
            "int8 drafter checkpoint format '{format}' != '{CHECKPOINT_FORMAT_INT8}'"
        );
        for (key, want) in [
            ("d_model", D_MODEL),
            ("d_ff", D_FF),
            ("time_feats", TIME_FEATS),
            ("seg", SEG),
            ("embed_dim", EMBED_DIM),
            ("diffusion_steps", DIFFUSION_STEPS),
        ] {
            let got = v.get(key)?.as_usize()?;
            ensure!(got == want, "int8 drafter checkpoint {key}={got}, this build wants {want}");
        }
        let q_all = v.get("q")?.as_f32_vec()?;
        let scales_all = v.get("scales")?.as_f32_vec()?;
        let biases_all = v.get("biases")?.as_f32_vec()?;
        let ln_all = v.get("ln")?.as_f32_vec()?;

        let want_q: usize = PROJ_DIMS.iter().map(|(_, i, o)| i * o).sum();
        let want_out: usize = PROJ_DIMS.iter().map(|(_, _, o)| o).sum();
        ensure!(q_all.len() == want_q, "q has {} entries, want {want_q}", q_all.len());
        ensure!(
            scales_all.len() == want_out,
            "scales has {} entries, want {want_out}",
            scales_all.len()
        );
        ensure!(
            biases_all.len() == want_out,
            "biases has {} entries, want {want_out}",
            biases_all.len()
        );
        ensure!(
            ln_all.len() == 6 * D_MODEL,
            "ln has {} entries, want {}",
            ln_all.len(),
            6 * D_MODEL
        );

        let mut qi = 0usize;
        let mut oi = 0usize;
        let mut take_proj = |in_dim: usize, out_dim: usize, name: &str| -> Result<Proj> {
            let mut q = vec![0i8; in_dim * out_dim];
            for (dst, &src) in q.iter_mut().zip(&q_all[qi..qi + in_dim * out_dim]) {
                ensure!(
                    src.fract() == 0.0 && (-127.0..=127.0).contains(&src),
                    "{name}: quantized weight {src} is not an int8 value"
                );
                *dst = src as i8;
            }
            let scales = scales_all[oi..oi + out_dim].to_vec();
            ensure!(
                scales.iter().all(|s| s.is_finite() && *s > 0.0),
                "{name}: non-positive quantization scale"
            );
            let b = biases_all[oi..oi + out_dim].to_vec();
            qi += in_dim * out_dim;
            oi += out_dim;
            Ok(Proj::Int8(QuantizedLinear { q, scales, b, in_dim, out_dim }))
        };
        let w_in = take_proj(IN_DIM, D_MODEL, "w_in")?;
        let wq = take_proj(D_MODEL, D_MODEL, "wq")?;
        let wk = take_proj(D_MODEL, D_MODEL, "wk")?;
        let wv = take_proj(D_MODEL, D_MODEL, "wv")?;
        let wo = take_proj(D_MODEL, D_MODEL, "wo")?;
        let w1 = take_proj(D_MODEL, D_FF, "w1")?;
        let w2 = take_proj(D_FF, D_MODEL, "w2")?;
        let w_out = take_proj(D_MODEL, SEG, "w_out")?;

        let mut lns = Vec::with_capacity(3);
        for i in 0..3 {
            let base = i * 2 * D_MODEL;
            lns.push(LayerNorm {
                gamma: ln_all[base..base + D_MODEL].to_vec(),
                beta: ln_all[base + D_MODEL..base + 2 * D_MODEL].to_vec(),
            });
        }
        let lnf = lns.pop().unwrap();
        let ln2 = lns.pop().unwrap();
        let ln1 = lns.pop().unwrap();

        Ok(Self { kern, w_in, ln1, wq, wk, wv, wo, ln2, w1, w2, lnf, w_out })
    }

    /// Save an int8 drafter checkpoint (v2 format).
    pub fn save(&self, path: &Path) -> Result<()> {
        self.to_json()?.save(path)
    }

    /// Load an int8 drafter checkpoint (v2 format).
    pub fn load_int8(path: &Path, kern: Kernels) -> Result<Self> {
        Self::from_json(&Json::load(path)?, kern)
            .with_context(|| format!("loading int8 drafter checkpoint {}", path.display()))
    }
}

/// A drafter checkpoint as selected at serve time: either the trainable
/// f32 model (v1 format) or an int8 quantized serving drafter (v2).
/// [`DrafterCheckpoint::load`] sniffs the format tag and honors an
/// explicit `--drafter-dtype` request, quantizing a v1 checkpoint
/// in-situ when int8 is asked for.
#[derive(Debug, Clone)]
pub enum DrafterCheckpoint {
    /// Full-precision drafter (v1 checkpoint).
    F32(DrafterModel),
    /// Int8 per-channel quantized drafter (v2 checkpoint, or v1
    /// quantized at load).
    Int8(ServingDrafter),
}

impl DrafterCheckpoint {
    /// Load a drafter checkpoint of either format. `want` is the
    /// explicit `--drafter-dtype` request: `None` serves the
    /// checkpoint's native dtype; `Some(Int8)` quantizes a v1 checkpoint
    /// in-situ; `Some(F32)` on a v2 checkpoint fails loudly (int8
    /// cannot be dequantized back to the trained weights).
    pub fn load(path: &Path, want: Option<DrafterDtype>) -> Result<Self> {
        let v = Json::load(path)?;
        let format = v
            .get("format")
            .and_then(|f| Ok(f.as_str()?.to_string()))
            .with_context(|| format!("drafter checkpoint {} has no format tag", path.display()))?;
        if format == CHECKPOINT_FORMAT_INT8 {
            ensure!(
                want != Some(DrafterDtype::F32),
                "{} is an int8 checkpoint; it cannot serve as --drafter-dtype f32",
                path.display()
            );
            let s = ServingDrafter::from_json(&v, Kernels::global())
                .with_context(|| format!("loading int8 drafter checkpoint {}", path.display()))?;
            return Ok(DrafterCheckpoint::Int8(s));
        }
        let model = DrafterModel::from_json(&v)
            .with_context(|| format!("loading drafter checkpoint {}", path.display()))?;
        match want {
            Some(DrafterDtype::Int8) => {
                Ok(DrafterCheckpoint::Int8(ServingDrafter::quantize(&model, Kernels::global())))
            }
            _ => Ok(DrafterCheckpoint::F32(model)),
        }
    }

    /// The dtype this checkpoint serves with.
    pub fn dtype(&self) -> DrafterDtype {
        match self {
            DrafterCheckpoint::F32(_) => DrafterDtype::F32,
            DrafterCheckpoint::Int8(_) => DrafterDtype::Int8,
        }
    }
}

/// Incremental causal decoding state: keys/values of the rollout's
/// earlier denoising-step tokens. `push` runs one token in O(context)
/// attention cost — the fused rollout is one growing sequence, not K
/// independent forwards.
pub struct RolloutState<'m> {
    d: &'m ServingDrafter,
    ks: Vec<Vec<f32>>,
    vs: Vec<Vec<f32>>,
}

impl RolloutState<'_> {
    /// Tokens pushed so far.
    pub fn len(&self) -> usize {
        self.ks.len()
    }

    /// True before the first token.
    pub fn is_empty(&self) -> bool {
        self.ks.is_empty()
    }

    /// Append the next denoising-step token and return its x̂0
    /// prediction. Identical arithmetic (and arithmetic order) to
    /// [`DrafterModel::forward_seq`] on the same kernel path, so a
    /// teacher-forced training sequence and an incremental rollout over
    /// the same tokens are bit-identical.
    pub fn push(&mut self, x: &[f32], t: usize, cond: &[f32]) -> Vec<f32> {
        let d = self.d;
        let kern = d.kern;
        let scale = 1.0 / (D_MODEL as f32).sqrt();
        let input = DrafterModel::token_input(x, t, cond);
        let mut e = vec![0.0f32; D_MODEL];
        d.w_in.forward(kern, &input, &mut e);
        let mut n1 = vec![0.0f32; D_MODEL];
        d.ln1.forward_with(kern, &e, &mut n1);
        let mut q = vec![0.0f32; D_MODEL];
        d.wq.forward(kern, &n1, &mut q);
        let mut k = vec![0.0f32; D_MODEL];
        d.wk.forward(kern, &n1, &mut k);
        let mut v = vec![0.0f32; D_MODEL];
        d.wv.forward(kern, &n1, &mut v);
        self.ks.push(k);
        self.vs.push(v);
        let j = self.ks.len() - 1;

        let mut attn = vec![0.0f32; j + 1];
        for i in 0..=j {
            attn[i] = kern.dot(&q, &self.ks[i]) * scale;
        }
        softmax_inplace(&mut attn);
        let mut ctx = vec![0.0f32; D_MODEL];
        for i in 0..=j {
            kern.add_scaled(&mut ctx, &self.vs[i], attn[i]);
        }
        let mut o = vec![0.0f32; D_MODEL];
        d.wo.forward(kern, &ctx, &mut o);
        let mut h = vec![0.0f32; D_MODEL];
        for i in 0..D_MODEL {
            h[i] = e[i] + o[i];
        }
        let mut n2 = vec![0.0f32; D_MODEL];
        d.ln2.forward_with(kern, &h, &mut n2);
        let mut f1 = vec![0.0f32; D_FF];
        d.w1.forward(kern, &n2, &mut f1);
        for a in f1.iter_mut() {
            *a = a.tanh();
        }
        let mut f2 = vec![0.0f32; D_MODEL];
        d.w2.forward(kern, &f1, &mut f2);
        let mut z = vec![0.0f32; D_MODEL];
        for i in 0..D_MODEL {
            z[i] = h[i] + f2[i];
        }
        let mut nf = vec![0.0f32; D_MODEL];
        d.lnf.forward_with(kern, &z, &mut nf);
        let mut y = vec![0.0f32; SEG];
        d.w_out.forward(kern, &nf, &mut y);
        for a in y.iter_mut() {
            *a = a.tanh();
        }
        y
    }
}

/// One active row of a drafter wave: the session's KV chain in the
/// shared arena plus the borrowed inputs for its next denoising-step
/// token.
#[derive(Debug)]
pub struct WaveInput<'a> {
    /// The session's chain in the wave's [`KvArena`].
    pub chain: ChainId,
    /// Current latent, SEG floats.
    pub x: &'a [f32],
    /// Timestep of this token.
    pub t: usize,
    /// Conditioning vector, EMBED_DIM floats.
    pub cond: &'a [f32],
}

/// Continuous-batched drafter decoding: many sessions' rollouts advance
/// one denoising-step token per [`WaveRollout::step`] wave, their KV
/// rows living in one shared per-shard [`KvArena`] instead of private
/// per-request buffers. Sessions join and leave the wave at step
/// granularity — a row just stops appearing in `rows` and its chain is
/// [`released`](WaveRollout::release).
///
/// The wave's eight projections run as blocked batched GEMVs over flat
/// row-major activation buffers ([`Kernels::gemv_rows`] /
/// [`QuantizedLinear::forward_rows`]): each weight row is loaded once
/// per wave and streamed against every session's activations, which is
/// where continuous batching actually converts into memory-bandwidth
/// savings. Scratch buffers are reused across waves (growing only to
/// the widest wave seen), so steady-state serving allocates nothing in
/// this path.
///
/// Determinism contract: batched GEMV is bitwise equal to the per-row
/// GEMV of [`RolloutState::push`], attention reads only the row's own
/// chain, and every per-row op (LayerNorm, softmax, tanh, residual
/// adds) is shared — so a wave-stepped rollout is **bit-identical** to
/// the serial per-request rollout no matter which sessions share its
/// waves, on every kernel path and either dtype.
#[derive(Debug)]
pub struct WaveRollout {
    arena: KvArena,
    inputs: Vec<f32>,
    e: Vec<f32>,
    n1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    attn: Vec<f32>,
    ctx: Vec<f32>,
    o: Vec<f32>,
    h: Vec<f32>,
    n2: Vec<f32>,
    f1: Vec<f32>,
    f2: Vec<f32>,
    z: Vec<f32>,
    nf: Vec<f32>,
}

impl WaveRollout {
    /// Empty wave state with a fresh [`KvArena`] of drafter-width rows.
    pub fn new() -> Self {
        Self {
            arena: KvArena::new(D_MODEL),
            inputs: Vec::new(),
            e: Vec::new(),
            n1: Vec::new(),
            q: Vec::new(),
            k: Vec::new(),
            v: Vec::new(),
            attn: Vec::new(),
            ctx: Vec::new(),
            o: Vec::new(),
            h: Vec::new(),
            n2: Vec::new(),
            f1: Vec::new(),
            f2: Vec::new(),
            z: Vec::new(),
            nf: Vec::new(),
        }
    }

    /// Open a KV chain for a session joining the wave.
    pub fn new_chain(&mut self) -> ChainId {
        self.arena.new_chain()
    }

    /// Reclaim a session's KV blocks when it leaves the wave.
    pub fn release(&mut self, chain: ChainId) {
        self.arena.release(chain)
    }

    /// The shared KV arena (metrics: high-water mark, blocks in use).
    pub fn arena(&self) -> &KvArena {
        &self.arena
    }

    /// Advance every row one denoising-step token. Writes the rows' x̂0
    /// predictions into `out` (rows.len()×SEG, request order), growing
    /// scratch only up to the widest wave ever seen.
    pub fn step(&mut self, d: &ServingDrafter, rows: &[WaveInput<'_>], out: &mut Vec<f32>) {
        let kern = d.kern;
        let scale = 1.0 / (D_MODEL as f32).sqrt();
        let n = rows.len();
        out.clear();
        out.resize(n * SEG, 0.0);
        if n == 0 {
            return;
        }

        // Phase 1 — assemble the wave's token inputs and run the
        // embedding + Q/K/V projections as batched GEMVs, each row then
        // appending its KV to its own chain.
        self.inputs.clear();
        for row in rows {
            debug_assert_eq!(row.x.len(), SEG);
            debug_assert_eq!(row.cond.len(), EMBED_DIM);
            self.inputs.extend_from_slice(row.x);
            self.inputs.extend_from_slice(&time_features(row.t));
            self.inputs.extend_from_slice(row.cond);
        }
        self.e.clear();
        self.e.resize(n * D_MODEL, 0.0);
        d.w_in.forward_rows(kern, &self.inputs, &mut self.e);
        self.n1.clear();
        self.n1.resize(n * D_MODEL, 0.0);
        for r in 0..n {
            d.ln1.forward_with(
                kern,
                &self.e[r * D_MODEL..(r + 1) * D_MODEL],
                &mut self.n1[r * D_MODEL..(r + 1) * D_MODEL],
            );
        }
        self.q.clear();
        self.q.resize(n * D_MODEL, 0.0);
        self.k.clear();
        self.k.resize(n * D_MODEL, 0.0);
        self.v.clear();
        self.v.resize(n * D_MODEL, 0.0);
        d.wq.forward_rows(kern, &self.n1, &mut self.q);
        d.wk.forward_rows(kern, &self.n1, &mut self.k);
        d.wv.forward_rows(kern, &self.n1, &mut self.v);
        for (r, row) in rows.iter().enumerate() {
            self.arena.push_kv(
                row.chain,
                &self.k[r * D_MODEL..(r + 1) * D_MODEL],
                &self.v[r * D_MODEL..(r + 1) * D_MODEL],
            );
        }

        // Phase 2 — causal attention: each row reads only its own
        // chain, so wave composition cannot influence any row's context.
        self.ctx.clear();
        self.ctx.resize(n * D_MODEL, 0.0);
        for (r, row) in rows.iter().enumerate() {
            let len = self.arena.chain_len(row.chain);
            self.attn.clear();
            self.attn.resize(len, 0.0);
            let q = &self.q[r * D_MODEL..(r + 1) * D_MODEL];
            for i in 0..len {
                self.attn[i] = kern.dot(q, self.arena.k_row(row.chain, i)) * scale;
            }
            softmax_inplace(&mut self.attn);
            let ctx = &mut self.ctx[r * D_MODEL..(r + 1) * D_MODEL];
            for i in 0..len {
                kern.add_scaled(ctx, self.arena.v_row(row.chain, i), self.attn[i]);
            }
        }

        // Phase 3 — attention output + MLP + head as batched GEMVs,
        // landing straight in the caller's output rows.
        self.o.clear();
        self.o.resize(n * D_MODEL, 0.0);
        d.wo.forward_rows(kern, &self.ctx, &mut self.o);
        self.h.clear();
        self.h.resize(n * D_MODEL, 0.0);
        for i in 0..n * D_MODEL {
            self.h[i] = self.e[i] + self.o[i];
        }
        self.n2.clear();
        self.n2.resize(n * D_MODEL, 0.0);
        for r in 0..n {
            d.ln2.forward_with(
                kern,
                &self.h[r * D_MODEL..(r + 1) * D_MODEL],
                &mut self.n2[r * D_MODEL..(r + 1) * D_MODEL],
            );
        }
        self.f1.clear();
        self.f1.resize(n * D_FF, 0.0);
        d.w1.forward_rows(kern, &self.n2, &mut self.f1);
        for a in self.f1.iter_mut() {
            *a = a.tanh();
        }
        self.f2.clear();
        self.f2.resize(n * D_MODEL, 0.0);
        d.w2.forward_rows(kern, &self.f1, &mut self.f2);
        self.z.clear();
        self.z.resize(n * D_MODEL, 0.0);
        for i in 0..n * D_MODEL {
            self.z[i] = self.h[i] + self.f2[i];
        }
        self.nf.clear();
        self.nf.resize(n * D_MODEL, 0.0);
        for r in 0..n {
            d.lnf.forward_with(
                kern,
                &self.z[r * D_MODEL..(r + 1) * D_MODEL],
                &mut self.nf[r * D_MODEL..(r + 1) * D_MODEL],
            );
        }
        d.w_out.forward_rows(kern, &self.nf, out);
        for a in out.iter_mut() {
            *a = a.tanh();
        }
    }
}

impl Default for WaveRollout {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::TempDir;
    use crate::util::Rng;

    fn small_inputs(l: usize, seed: u64) -> (Vec<f32>, Vec<usize>, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let xs = rng.normal_vec(l * SEG);
        let ts: Vec<usize> = (0..l).map(|j| 60 - j).collect();
        let cond = rng.normal_vec(EMBED_DIM);
        (xs, ts, cond)
    }

    fn solo(d: &ServingDrafter, xs: &[f32], ts: &[usize], cond: &[f32]) -> Vec<f32> {
        let mut roll = d.start_rollout();
        let mut out = Vec::new();
        for j in 0..ts.len() {
            out.extend(roll.push(&xs[j * SEG..(j + 1) * SEG], ts[j], cond));
        }
        out
    }

    #[test]
    fn rollout_state_matches_forward_seq_bitwise() {
        let mut rng = Rng::seed_from_u64(0);
        let model = DrafterModel::init(&mut rng);
        let serving = ServingDrafter::from_model(&model, Kernels::global());
        let (xs, ts, cond) = small_inputs(5, 1);
        let (seq_out, _) = model.forward_seq(&xs, &ts, &cond);
        let mut roll = serving.start_rollout();
        for j in 0..5 {
            let y = roll.push(&xs[j * SEG..(j + 1) * SEG], ts[j], &cond);
            assert_eq!(&seq_out[j * SEG..(j + 1) * SEG], &y[..], "token {j}");
        }
        assert_eq!(roll.len(), 5);
        assert!(!roll.is_empty());
    }

    /// The wave-vs-serial bit-identity contract, exercised for a given
    /// serving drafter (f32 on any path, or int8): three sessions share
    /// one arena — A spans waves 0..5, B leaves mid-stream after wave 2,
    /// C joins mid-stream at wave 3 — and every token must equal the
    /// session's solo RolloutState rollout bitwise.
    fn wave_matches_serial(serving: &ServingDrafter) {
        let (xs_a, ts_a, cond_a) = small_inputs(5, 11);
        let (xs_b, ts_b, cond_b) = small_inputs(3, 12);
        let (xs_c, ts_c, cond_c) = small_inputs(2, 13);

        let want_a = solo(serving, &xs_a, &ts_a, &cond_a);
        let want_b = solo(serving, &xs_b, &ts_b, &cond_b);
        let want_c = solo(serving, &xs_c, &ts_c, &cond_c);

        let mut wave = WaveRollout::new();
        let ca = wave.new_chain();
        let cb = wave.new_chain();
        let mut cc = None;
        let (mut got_a, mut got_b, mut got_c) = (Vec::new(), Vec::new(), Vec::new());
        let mut out = Vec::new();
        for j in 0..5 {
            let mut rows = vec![WaveInput {
                chain: ca,
                x: &xs_a[j * SEG..(j + 1) * SEG],
                t: ts_a[j],
                cond: &cond_a,
            }];
            if j < 3 {
                rows.push(WaveInput {
                    chain: cb,
                    x: &xs_b[j * SEG..(j + 1) * SEG],
                    t: ts_b[j],
                    cond: &cond_b,
                });
            }
            if j >= 3 {
                let chain = *cc.get_or_insert_with(|| wave.new_chain());
                let jc = j - 3;
                rows.push(WaveInput {
                    chain,
                    x: &xs_c[jc * SEG..(jc + 1) * SEG],
                    t: ts_c[jc],
                    cond: &cond_c,
                });
            }
            wave.step(serving, &rows, &mut out);
            got_a.extend_from_slice(&out[..SEG]);
            if j < 3 {
                got_b.extend_from_slice(&out[SEG..2 * SEG]);
            } else {
                got_c.extend_from_slice(&out[SEG..2 * SEG]);
            }
            if j == 2 {
                wave.release(cb);
            }
        }
        wave.release(ca);
        wave.release(cc.unwrap());
        assert_eq!(got_a, want_a, "session A bitwise");
        assert_eq!(got_b, want_b, "session B bitwise");
        assert_eq!(got_c, want_c, "session C bitwise");
        assert_eq!(wave.arena().blocks_in_use(), 0, "round-end reclamation");
        assert!(wave.arena().high_water() >= 2, "arena really was shared");
    }

    #[test]
    fn wave_rollout_matches_rollout_state_bitwise_on_both_paths() {
        let mut rng = Rng::seed_from_u64(7);
        let model = DrafterModel::init(&mut rng);
        for kern in [Kernels::scalar(), Kernels::lanes()] {
            wave_matches_serial(&ServingDrafter::from_model(&model, kern));
        }
    }

    #[test]
    fn int8_wave_rollout_matches_int8_serial_bitwise() {
        let mut rng = Rng::seed_from_u64(8);
        let model = DrafterModel::init(&mut rng);
        for kern in [Kernels::scalar(), Kernels::lanes()] {
            let quantized = ServingDrafter::quantize(&model, kern);
            assert_eq!(quantized.dtype(), DrafterDtype::Int8);
            wave_matches_serial(&quantized);
        }
    }

    #[test]
    fn int8_outputs_track_f32_outputs() {
        // Not bit-identity (quantization is lossy by design) — but the
        // tanh-bounded x̂0 predictions of the int8 drafter must stay
        // close to the f32 drafter's on identical rollouts.
        let mut rng = Rng::seed_from_u64(9);
        let model = DrafterModel::init(&mut rng);
        let kern = Kernels::lanes();
        let f32d = ServingDrafter::from_model(&model, kern);
        let i8d = ServingDrafter::quantize(&model, kern);
        let (xs, ts, cond) = small_inputs(4, 10);
        let yf = solo(&f32d, &xs, &ts, &cond);
        let yq = solo(&i8d, &xs, &ts, &cond);
        let max_err = yf.iter().zip(&yq).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_err < 0.05, "int8 drifted {max_err} from f32 on an untrained model");
    }

    #[test]
    fn int8_checkpoint_roundtrips_bitwise() {
        let mut rng = Rng::seed_from_u64(21);
        let model = DrafterModel::init(&mut rng);
        let kern = Kernels::global();
        let quantized = ServingDrafter::quantize(&model, kern);
        let dir = TempDir::new("drafter_int8_ckpt");
        let path = dir.path().join("drafter_int8.json");
        quantized.save(&path).unwrap();
        let loaded = ServingDrafter::load_int8(&path, kern).unwrap();
        assert_eq!(loaded.dtype(), DrafterDtype::Int8);
        let (xs, ts, cond) = small_inputs(4, 22);
        assert_eq!(
            solo(&quantized, &xs, &ts, &cond),
            solo(&loaded, &xs, &ts, &cond),
            "int8 JSON roundtrip must preserve every bit"
        );
    }

    #[test]
    fn f32_drafters_refuse_the_int8_checkpoint_format() {
        let mut rng = Rng::seed_from_u64(23);
        let model = DrafterModel::init(&mut rng);
        let f32d = ServingDrafter::from_model(&model, Kernels::global());
        assert!(f32d.to_json().is_err(), "f32 drafters must not claim the int8 format");
    }

    #[test]
    fn int8_checkpoint_drift_fails_loudly() {
        let mut rng = Rng::seed_from_u64(24);
        let model = DrafterModel::init(&mut rng);
        let kern = Kernels::global();
        let quantized = ServingDrafter::quantize(&model, kern);
        let good = quantized.to_json().unwrap();

        let mut bad_dim = good.clone();
        if let Json::Obj(m) = &mut bad_dim {
            m.insert("d_model".into(), Json::Num((D_MODEL + 1) as f64));
        }
        let err = ServingDrafter::from_json(&bad_dim, kern).unwrap_err();
        assert!(err.to_string().contains("d_model"), "{err:#}");

        let mut bad_fmt = good.clone();
        if let Json::Obj(m) = &mut bad_fmt {
            m.insert("format".into(), Json::Str("bogus".into()));
        }
        assert!(ServingDrafter::from_json(&bad_fmt, kern).is_err());
    }

    #[test]
    fn checkpoint_selector_honors_dtype_requests() {
        let mut rng = Rng::seed_from_u64(25);
        let model = DrafterModel::init(&mut rng);
        let dir = TempDir::new("drafter_ckpt_select");
        let v1 = dir.path().join("drafter_v1.json");
        model.save(&v1).unwrap();
        let v2 = dir.path().join("drafter_int8.json");
        ServingDrafter::quantize(&model, Kernels::global()).save(&v2).unwrap();

        // v1 native → f32; v1 + int8 request → quantized in-situ.
        assert_eq!(DrafterCheckpoint::load(&v1, None).unwrap().dtype(), DrafterDtype::F32);
        let q = DrafterCheckpoint::load(&v1, Some(DrafterDtype::Int8)).unwrap();
        assert_eq!(q.dtype(), DrafterDtype::Int8);
        // v2 native → int8; v2 + f32 request → loud error.
        assert_eq!(DrafterCheckpoint::load(&v2, None).unwrap().dtype(), DrafterDtype::Int8);
        assert!(DrafterCheckpoint::load(&v2, Some(DrafterDtype::F32)).is_err());

        // In-situ quantization must equal quantize-then-load bitwise.
        let (xs, ts, cond) = small_inputs(3, 26);
        let (DrafterCheckpoint::Int8(a), DrafterCheckpoint::Int8(b)) =
            (q, DrafterCheckpoint::load(&v2, Some(DrafterDtype::Int8)).unwrap())
        else {
            panic!("both must be int8");
        };
        assert_eq!(solo(&a, &xs, &ts, &cond), solo(&b, &xs, &ts, &cond));
    }

    #[test]
    fn dtype_flags_parse_and_name_roundtrip() {
        for d in [DrafterDtype::F32, DrafterDtype::Int8] {
            assert_eq!(DrafterDtype::parse(d.name()).unwrap(), d);
        }
        assert!(DrafterDtype::parse("fp16").is_err());
    }
}
