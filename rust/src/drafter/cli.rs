//! `ts-dp distill-drafter` — distill a Transformer drafter from the base
//! model over the env fleet and write a serve-time checkpoint — and
//! `ts-dp quantize-drafter` — convert a v1 f32 checkpoint into the int8
//! per-channel v2 format.

use crate::config::{DemoStyle, SpecParams, Task};
use crate::coordinator::cli::backend_choice;
use crate::drafter::model::DrafterModel;
use crate::drafter::serving::ServingDrafter;
use crate::drafter::train::{accept_scorecard, collect_trajectories, train_on, DistillConfig};
use crate::kernels::Kernels;
use crate::util::cli::Args;
use anyhow::{Context, Result};
use std::path::PathBuf;

/// Entry point for `ts-dp distill-drafter`.
///
/// Collects target-only denoising trajectories from the selected backend
/// (`--backend artifacts|mock`), trains the drafter on MSE + K-step
/// rollout-consistency windows, reports the measured accept-rate
/// improvement over an untrained drafter, and saves the checkpoint that
/// `serve --drafter` / `load-sweep --drafter` / `episode --drafter`
/// load.
pub fn cmd_distill(args: &Args) -> Result<()> {
    let out = PathBuf::from(args.get_or("out", "artifacts/drafter.json"));
    let style = DemoStyle::parse(&args.get_or("style", "ph")).context("--style must be ph|mh")?;
    let tasks: Vec<Task> = match args.get("tasks") {
        None => vec![Task::Lift, Task::Can, Task::PushT, Task::Kitchen],
        Some(spec) => spec
            .split(',')
            .map(|s| Task::parse(s.trim()).with_context(|| format!("unknown task '{s}'")))
            .collect::<Result<_>>()?,
    };
    let cfg = DistillConfig {
        tasks,
        style,
        trajectories_per_task: args.get_usize("trajectories", 6)?,
        window: args.get_usize("window", 8)?,
        steps: args.get_usize("steps", 800)?,
        batch: args.get_usize("batch", 8)?,
        lr: args.get_f32("lr", 3e-3)?,
        single_frac: args.get_f32("single-frac", 0.25)?,
        seed: args.get_u64("seed", 0)?,
    };

    let choice = backend_choice(args)?;
    let den = choice.build()?;
    println!(
        "collecting {} trajectories ({} tasks x {}) from the target model...",
        cfg.tasks.len() * cfg.trajectories_per_task,
        cfg.tasks.len(),
        cfg.trajectories_per_task
    );
    let trajs = collect_trajectories(
        den.as_ref(),
        &cfg.tasks,
        cfg.style,
        cfg.trajectories_per_task,
        cfg.seed,
    )?;

    println!("{:<8} {:>14}", "step", "x0 mse");
    let (model, report) = train_on(&trajs, &cfg, None, |s| {
        println!("{:<8} {:>14.6}", s.step, s.loss);
    })?;
    println!(
        "trained {} params on {} trajectories, final loss {:.6}",
        model.n_params(),
        report.trajectories,
        report.final_loss
    );

    // Accept-rate scorecard: untrained vs distilled, measured by
    // actually serving speculative segments over fresh env rollouts.
    // The collection backend is reused for the untrained wrapper; only
    // the distilled wrapper needs a second replica build.
    let (before, after) = accept_scorecard(
        den,
        choice.build()?,
        &model,
        &cfg.tasks,
        cfg.style,
        2,
        SpecParams::fixed_default(),
        cfg.seed ^ 0x5eed_acce,
    )?;
    println!(
        "accept rate: untrained {:.1}% (nfe/seg {:.1}) -> distilled {:.1}% (nfe/seg {:.1})",
        before.accept_rate * 100.0,
        before.mean_nfe,
        after.accept_rate * 100.0,
        after.mean_nfe
    );

    model.save(&out)?;
    println!("saved drafter checkpoint to {}", out.display());
    Ok(())
}

/// Entry point for `ts-dp quantize-drafter --drafter IN --out OUT`.
///
/// Loads a v1 f32 drafter checkpoint, quantizes every projection to
/// int8 per-output-channel (absmax scales; biases and LayerNorms stay
/// f32), and writes the v2 checkpoint that `serve --drafter OUT` (or any
/// `--drafter-dtype int8` run) serves. Quantization is one-way: keep the
/// v1 checkpoint if you still need the trainable weights.
pub fn cmd_quantize(args: &Args) -> Result<()> {
    let input = PathBuf::from(
        args.get("drafter")
            .context("quantize-drafter needs --drafter CHECKPOINT (a v1 f32 checkpoint)")?,
    );
    let out = PathBuf::from(args.get_or("out", "artifacts/drafter_int8.json"));
    let model = DrafterModel::load(&input)
        .with_context(|| format!("loading f32 drafter checkpoint {}", input.display()))?;
    let quantized = ServingDrafter::quantize(&model, Kernels::global());
    quantized.save(&out)?;
    let v1 = std::fs::metadata(&input).map(|m| m.len()).unwrap_or(0);
    let v2 = std::fs::metadata(&out).map(|m| m.len()).unwrap_or(0);
    println!(
        "quantized {} ({} params) -> {} ({:.1}% of the f32 checkpoint bytes)",
        input.display(),
        model.n_params(),
        out.display(),
        100.0 * v2 as f64 / v1.max(1) as f64
    );
    Ok(())
}
