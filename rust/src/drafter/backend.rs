//! [`DistilledDrafter`]: swap a distilled Transformer drafter under any
//! base [`Denoiser`] at serve time.
//!
//! Every `target_*` call (and `encode`) delegates to the wrapped base
//! backend bit-for-bit — the verify/accept path is untouched, so the
//! speculative engine's losslessness guarantee is preserved no matter
//! how good or bad the drafter is; the drafter only moves the accept
//! rate. `drafter_step` and `drafter_rollout` are served by the model:
//! the rollout is **natively fused** (one KV-cached causal sequence per
//! round, `Some` for every k — no per-k AOT artifact required), and NFE
//! accounting lands on the base backend's counter at the paper's 1/8
//! rate per drafter token.
//!
//! Under the serving fleet, `drafter_rollout_many` additionally batches
//! *across* requests: every in-flight draft advances one denoising step
//! per [`WaveRollout`] wave over a shared per-shard KV arena
//! (`drafter::arena`), bit-identical to per-request rollouts because
//! each row's arithmetic order is unchanged and attention never leaves
//! the row's own KV chain.

use crate::config::{ACT_DIM, DIFFUSION_STEPS, HORIZON};
use crate::diffusion::DdpmSchedule;
use crate::drafter::model::{eps_from_x0, DrafterModel};
use crate::drafter::serving::{
    DrafterCheckpoint, DrafterDtype, ServingDrafter, WaveInput, WaveRollout,
};
use crate::kernels::Kernels;
use crate::policy::{Denoiser, RolloutRequest};
use crate::runtime::NfeCounter;
use anyhow::{ensure, Result};
use std::cell::RefCell;

/// Flattened segment size.
const SEG: usize = HORIZON * ACT_DIM;

/// A base denoiser with its drafter head replaced by a distilled
/// Transformer drafter (see `drafter::train` for how one is produced and
/// `ts-dp distill-drafter` / `serve --drafter` for the CLI path). The
/// drafter executes through [`ServingDrafter`] — process-wide kernel
/// dispatch, f32 or int8 per-channel quantized weights.
pub struct DistilledDrafter {
    base: Box<dyn Denoiser>,
    serving: ServingDrafter,
    /// The trainable f32 model, retained when this wrapper was built
    /// from one (int8 checkpoints have no trainable form).
    model: Option<DrafterModel>,
    sched: DdpmSchedule,
    /// Shared KV arena + scratch for the wave-batched rollout path.
    /// Interior mutability because [`Denoiser`] methods take `&self`;
    /// denoisers are not `Send` and each shard owns its replica on one
    /// thread, so a `RefCell` is sufficient (never contended).
    wave: RefCell<WaveRollout>,
}

impl DistilledDrafter {
    /// Wrap `base`, serving drafter calls from `model` at full f32
    /// precision (bit-exact with the pre-kernels serving path under
    /// `TSDP_KERNELS=scalar`).
    pub fn new(base: Box<dyn Denoiser>, model: DrafterModel) -> Self {
        let serving = ServingDrafter::from_model(&model, Kernels::global());
        Self::assemble(base, serving, Some(model))
    }

    /// Wrap `base`, serving drafter calls from an int8 per-channel
    /// quantization of `model`.
    pub fn new_int8(base: Box<dyn Denoiser>, model: &DrafterModel) -> Self {
        Self::assemble(base, ServingDrafter::quantize(model, Kernels::global()), None)
    }

    /// Wrap `base`, serving drafter calls from an already-built serving
    /// drafter (e.g. one loaded from an int8 v2 checkpoint).
    pub fn from_serving(base: Box<dyn Denoiser>, serving: ServingDrafter) -> Self {
        Self::assemble(base, serving, None)
    }

    /// Wrap `base`, serving drafter calls from a loaded checkpoint of
    /// either dtype.
    pub fn from_checkpoint(base: Box<dyn Denoiser>, ckpt: &DrafterCheckpoint) -> Self {
        match ckpt {
            DrafterCheckpoint::F32(m) => Self::new(base, m.clone()),
            DrafterCheckpoint::Int8(s) => Self::from_serving(base, s.clone()),
        }
    }

    fn assemble(
        base: Box<dyn Denoiser>,
        serving: ServingDrafter,
        model: Option<DrafterModel>,
    ) -> Self {
        Self {
            base,
            serving,
            model,
            sched: DdpmSchedule::cosine(DIFFUSION_STEPS),
            wave: RefCell::new(WaveRollout::new()),
        }
    }

    /// The trainable f32 model, when this wrapper still has one (int8
    /// checkpoints don't — quantization is one-way).
    pub fn model(&self) -> Option<&DrafterModel> {
        self.model.as_ref()
    }

    /// Weight dtype the drafter serves with.
    pub fn dtype(&self) -> DrafterDtype {
        self.serving.dtype()
    }

    /// Peak KV-block demand of the wave arena since construction.
    pub fn arena_high_water(&self) -> usize {
        self.wave.borrow().arena().high_water()
    }
}

impl Denoiser for DistilledDrafter {
    fn encode(&self, obs: &[f32]) -> Result<Vec<f32>> {
        self.base.encode(obs)
    }

    fn target_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        self.base.target_step(x, t, cond)
    }

    fn target_verify(&self, xs: &[f32], ts: &[f32], cond: &[f32]) -> Result<Vec<f32>> {
        self.base.target_verify(xs, ts, cond)
    }

    fn target_verify_many(&self, xs: &[f32], ts: &[f32], conds: &[f32]) -> Result<Vec<f32>> {
        self.base.target_verify_many(xs, ts, conds)
    }

    fn drafter_step(&self, x: &[f32], t: usize, cond: &[f32]) -> Result<Vec<f32>> {
        ensure!(x.len() == SEG, "drafter_step x len {}", x.len());
        self.base.nfe().count_drafter(1);
        let x0 = self.serving.start_rollout().push(x, t, cond);
        let mut eps = vec![0.0f32; SEG];
        eps_from_x0(&self.sched, t, x, &x0, &mut eps);
        Ok(eps)
    }

    fn drafter_rollout(
        &self,
        k: usize,
        x: &[f32],
        t0: usize,
        cond: &[f32],
        noise: &[f32],
    ) -> Result<Option<(Vec<f32>, Vec<f32>)>> {
        ensure!(k >= 1, "drafter_rollout k must be >= 1");
        ensure!(t0 >= k, "drafter_rollout needs t0 >= k (got t0={t0}, k={k})");
        ensure!(x.len() == SEG, "drafter_rollout x len {}", x.len());
        ensure!(noise.len() == k * SEG, "drafter_rollout noise len {}", noise.len());
        let mut state = self.serving.start_rollout();
        let mut samples = vec![0.0f32; k * SEG];
        let mut means = vec![0.0f32; k * SEG];
        let mut cur = x.to_vec();
        let mut eps = vec![0.0f32; SEG];
        let mut x0_scratch = vec![0.0f32; SEG];
        for j in 0..k {
            let t = t0 - j;
            let x0 = state.push(&cur, t, cond);
            eps_from_x0(&self.sched, t, &cur, &x0, &mut eps);
            {
                let sample = &mut samples[j * SEG..(j + 1) * SEG];
                // `means` and `samples` are distinct Vecs, so the two
                // mutable row borrows never alias.
                let mean = &mut means[j * SEG..(j + 1) * SEG];
                self.sched.step_into(
                    t,
                    &cur,
                    &eps,
                    &noise[j * SEG..(j + 1) * SEG],
                    &mut x0_scratch,
                    sample,
                    mean,
                );
            }
            cur.copy_from_slice(&samples[j * SEG..(j + 1) * SEG]);
        }
        self.base.nfe().count_drafter(k);
        Ok(Some((samples, means)))
    }

    /// Continuous-batched rollouts: every request advances one denoising
    /// step per wave over the shared KV arena, requests leaving the wave
    /// as their `k` is exhausted. Per-row arithmetic order is exactly
    /// [`DistilledDrafter::drafter_rollout`]'s (same `WaveRollout` ==
    /// `RolloutState` kernel, same DDPM step, same pre-drawn noise), so
    /// the results are bit-identical to serial serving for any wave
    /// composition.
    fn drafter_rollout_many(
        &self,
        reqs: &[RolloutRequest<'_>],
    ) -> Result<Vec<Option<(Vec<f32>, Vec<f32>)>>> {
        if reqs.is_empty() {
            return Ok(Vec::new());
        }
        for r in reqs {
            ensure!(r.k >= 1, "drafter_rollout_many k must be >= 1");
            ensure!(r.t0 >= r.k, "drafter_rollout_many needs t0 >= k (t0={}, k={})", r.t0, r.k);
            ensure!(r.x.len() == SEG, "drafter_rollout_many x len {}", r.x.len());
            ensure!(
                r.noise.len() == r.k * SEG,
                "drafter_rollout_many noise len {}",
                r.noise.len()
            );
        }
        let mut wave = self.wave.borrow_mut();
        let n = reqs.len();
        let chains: Vec<_> = reqs.iter().map(|_| wave.new_chain()).collect();
        let mut samples: Vec<Vec<f32>> = reqs.iter().map(|r| vec![0.0f32; r.k * SEG]).collect();
        let mut means: Vec<Vec<f32>> = reqs.iter().map(|r| vec![0.0f32; r.k * SEG]).collect();
        let mut curs: Vec<Vec<f32>> = reqs.iter().map(|r| r.x.to_vec()).collect();
        let max_k = reqs.iter().map(|r| r.k).max().unwrap_or(0);
        let mut x0s = Vec::new();
        let mut eps = vec![0.0f32; SEG];
        let mut x0_scratch = vec![0.0f32; SEG];
        let mut active: Vec<usize> = Vec::with_capacity(n);
        for j in 0..max_k {
            active.clear();
            active.extend((0..n).filter(|&i| j < reqs[i].k));
            {
                // `rows` borrows `curs` immutably; scoped so the DDPM
                // step below can write the next latents.
                let rows: Vec<WaveInput<'_>> = active
                    .iter()
                    .map(|&i| WaveInput {
                        chain: chains[i],
                        x: &curs[i],
                        t: reqs[i].t0 - j,
                        cond: reqs[i].cond,
                    })
                    .collect();
                wave.step(&self.serving, &rows, &mut x0s);
            }
            for (slot, &i) in active.iter().enumerate() {
                let t = reqs[i].t0 - j;
                let x0 = &x0s[slot * SEG..(slot + 1) * SEG];
                eps_from_x0(&self.sched, t, &curs[i], x0, &mut eps);
                {
                    let sample = &mut samples[i][j * SEG..(j + 1) * SEG];
                    let mean = &mut means[i][j * SEG..(j + 1) * SEG];
                    self.sched.step_into(
                        t,
                        &curs[i],
                        &eps,
                        &reqs[i].noise[j * SEG..(j + 1) * SEG],
                        &mut x0_scratch,
                        sample,
                        mean,
                    );
                }
                curs[i].copy_from_slice(&samples[i][j * SEG..(j + 1) * SEG]);
            }
        }
        for c in chains {
            wave.release(c);
        }
        self.base.nfe().count_drafter(reqs.iter().map(|r| r.k).sum::<usize>());
        Ok(samples.into_iter().zip(means).map(|(s, m)| Some((s, m))).collect())
    }

    fn kv_arena_high_water(&self) -> Option<usize> {
        Some(self.arena_high_water())
    }

    fn nfe(&self) -> &NfeCounter {
        self.base.nfe()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SpecParams, OBS_DIM, VERIFY_BATCH};
    use crate::policy::mock::MockDenoiser;
    use crate::speculative::{SegmentTrace, SpecEngine};
    use crate::util::Rng;

    fn backend(seed: u64) -> DistilledDrafter {
        let mut rng = Rng::seed_from_u64(seed);
        DistilledDrafter::new(
            Box::new(MockDenoiser::with_bias(0.0)),
            DrafterModel::init(&mut rng),
        )
    }

    #[test]
    fn rollout_is_natively_fused_for_every_k() {
        let den = backend(0);
        let cond = den.encode(&vec![0.2; OBS_DIM]).unwrap();
        let mut rng = Rng::seed_from_u64(1);
        let x = rng.normal_vec(SEG);
        for k in [1usize, 4, 16] {
            let noise = rng.normal_vec(k * SEG);
            let out = den.drafter_rollout(k, &x, 60, &cond, &noise).unwrap();
            let (samples, means) = out.expect("distilled drafter must fuse every k");
            assert_eq!(samples.len(), k * SEG);
            assert_eq!(means.len(), k * SEG);
            for v in samples.iter().chain(means.iter()) {
                assert!(v.is_finite());
            }
        }
    }

    #[test]
    fn rollout_first_step_matches_drafter_step() {
        // Token 0 of a rollout has no context, so it must agree bitwise
        // with the single-step drafter call through the same DDPM step.
        let den = backend(2);
        let cond = den.encode(&vec![0.4; OBS_DIM]).unwrap();
        let mut rng = Rng::seed_from_u64(3);
        let x = rng.normal_vec(SEG);
        let t0 = 50;
        let noise = rng.normal_vec(4 * SEG);
        let (_, means) = den.drafter_rollout(4, &x, t0, &cond, &noise).unwrap().unwrap();
        let eps = den.drafter_step(&x, t0, &cond).unwrap();
        let sched = DdpmSchedule::cosine(DIFFUSION_STEPS);
        let mut x0 = vec![0.0; SEG];
        let mut mu = vec![0.0; SEG];
        sched.predict_x0(t0, &x, &eps, &mut x0);
        sched.posterior_mean(t0, &x, &x0, &mut mu);
        assert_eq!(&means[..SEG], &mu[..]);
    }

    #[test]
    fn target_calls_delegate_bit_identically() {
        let den = backend(4);
        let reference = MockDenoiser::with_bias(0.0);
        let cond = den.encode(&vec![0.1; OBS_DIM]).unwrap();
        assert_eq!(cond, reference.encode(&vec![0.1; OBS_DIM]).unwrap());
        let mut rng = Rng::seed_from_u64(5);
        let x = rng.normal_vec(SEG);
        assert_eq!(
            den.target_step(&x, 30, &cond).unwrap(),
            reference.target_step(&x, 30, &cond).unwrap()
        );
        let mut xs = Vec::new();
        let mut ts = Vec::new();
        for b in 0..VERIFY_BATCH {
            xs.extend(rng.normal_vec(SEG));
            ts.push((b * 3 % DIFFUSION_STEPS) as f32);
        }
        assert_eq!(
            den.target_verify(&xs, &ts, &cond).unwrap(),
            reference.target_verify(&xs, &ts, &cond).unwrap()
        );
    }

    #[test]
    fn nfe_accounting_is_one_eighth_per_drafter_token() {
        let den = backend(6);
        let cond = den.encode(&vec![0.3; OBS_DIM]).unwrap();
        let mut rng = Rng::seed_from_u64(7);
        let x = rng.normal_vec(SEG);
        let noise = rng.normal_vec(4 * SEG);
        den.drafter_rollout(4, &x, 60, &cond, &noise).unwrap();
        assert_eq!(den.nfe().nfe(), 0.5, "k=4 rollout costs 4/8 NFE");
        den.drafter_step(&x, 60, &cond).unwrap();
        assert_eq!(den.nfe().nfe(), 0.625);
        den.target_step(&x, 60, &cond).unwrap();
        assert_eq!(den.nfe().nfe(), 1.625, "target delegation shares the counter");
    }

    #[test]
    fn rollout_shape_errors_are_loud() {
        let den = backend(8);
        let cond = den.encode(&vec![0.0; OBS_DIM]).unwrap();
        let x = vec![0.0f32; SEG];
        assert!(den.drafter_rollout(4, &x, 60, &cond, &[0.0; 7]).is_err());
        assert!(den.drafter_rollout(8, &x, 4, &cond, &vec![0.0; 8 * SEG]).is_err());
    }

    /// Batch of heterogeneous-k rollout requests over `den`, with
    /// per-request inputs derived from `seed`. Returns owned inputs so
    /// callers can build `RolloutRequest` borrows from them.
    fn wave_inputs(
        den: &DistilledDrafter,
        ks: &[usize],
        seed: u64,
    ) -> (Vec<Vec<f32>>, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        let mut rng = Rng::seed_from_u64(seed);
        let conds: Vec<Vec<f32>> = ks
            .iter()
            .map(|_| den.encode(&rng.normal_vec(OBS_DIM)).unwrap())
            .collect();
        let xs: Vec<Vec<f32>> = ks.iter().map(|_| rng.normal_vec(SEG)).collect();
        let noises: Vec<Vec<f32>> = ks.iter().map(|&k| rng.normal_vec(k * SEG)).collect();
        (conds, xs, noises)
    }

    #[test]
    fn rollout_many_matches_per_request_bitwise() {
        // Tentpole acceptance: heterogeneous ks (sessions leave the wave
        // at step granularity as their k is exhausted) must be
        // bit-identical — samples AND means — to serial per-request
        // rollouts, with identical NFE.
        let ks = [1usize, 8, 16, 3];
        let t0 = 60;
        let batched = backend(20);
        let serial = backend(20);
        let (conds, xs, noises) = wave_inputs(&batched, &ks, 21);

        let reqs: Vec<RolloutRequest<'_>> = ks
            .iter()
            .enumerate()
            .map(|(i, &k)| RolloutRequest {
                k,
                x: &xs[i],
                t0,
                cond: &conds[i],
                noise: &noises[i],
            })
            .collect();
        let got = batched.drafter_rollout_many(&reqs).unwrap();
        assert_eq!(got.len(), ks.len());
        for (i, &k) in ks.iter().enumerate() {
            let want = serial
                .drafter_rollout(k, &xs[i], t0, &conds[i], &noises[i])
                .unwrap()
                .unwrap();
            let (gs, gm) = got[i].as_ref().expect("wave path must fuse every request");
            assert_eq!(gs, &want.0, "request {i} samples");
            assert_eq!(gm, &want.1, "request {i} means");
        }
        assert_eq!(batched.nfe().nfe(), serial.nfe().nfe(), "NFE accounting");
        assert!(batched.arena_high_water() > 0, "arena really engaged");
        assert_eq!(serial.arena_high_water(), 0, "serial path never touches the arena");
    }

    #[test]
    fn wave_state_is_clean_across_rounds() {
        // Round 2 over the same arena (blocks now reused from the free
        // list) must still match serial exactly — no state can leak
        // between rounds, and steady state allocates no new blocks.
        let ks = [8usize, 8, 4];
        let batched = backend(22);
        let serial = backend(22);
        for round in 0..3u64 {
            let (conds, xs, noises) = wave_inputs(&batched, &ks, 30 + round);
            let reqs: Vec<RolloutRequest<'_>> = ks
                .iter()
                .enumerate()
                .map(|(i, &k)| RolloutRequest {
                    k,
                    x: &xs[i],
                    t0: 55,
                    cond: &conds[i],
                    noise: &noises[i],
                })
                .collect();
            let got = batched.drafter_rollout_many(&reqs).unwrap();
            for (i, &k) in ks.iter().enumerate() {
                let want = serial
                    .drafter_rollout(k, &xs[i], 55, &conds[i], &noises[i])
                    .unwrap()
                    .unwrap();
                assert_eq!(got[i].as_ref().unwrap().0, want.0, "round {round} request {i}");
            }
        }
        // 8+8+4 tokens = 2+2+1 blocks of 4; demand peaks once and every
        // later round reuses those blocks.
        assert_eq!(batched.arena_high_water(), 5, "steady-state block demand");
    }

    #[test]
    fn int8_backend_waves_match_int8_serial_bitwise() {
        // The wave-vs-serial bit-identity contract must survive
        // quantization: an int8 drafter's batched rollouts equal its own
        // serial rollouts bitwise (int8 vs f32 parity is a separate,
        // accept-rate-level question).
        let mut rng = Rng::seed_from_u64(40);
        let model = DrafterModel::init(&mut rng);
        let batched =
            DistilledDrafter::new_int8(Box::new(MockDenoiser::with_bias(0.0)), &model);
        let serial =
            DistilledDrafter::new_int8(Box::new(MockDenoiser::with_bias(0.0)), &model);
        assert_eq!(batched.dtype(), crate::drafter::serving::DrafterDtype::Int8);
        assert!(batched.model().is_none(), "int8 wrappers drop the trainable form");
        let ks = [2usize, 8, 5];
        let (conds, xs, noises) = wave_inputs(&batched, &ks, 41);
        let reqs: Vec<RolloutRequest<'_>> = ks
            .iter()
            .enumerate()
            .map(|(i, &k)| RolloutRequest {
                k,
                x: &xs[i],
                t0: 58,
                cond: &conds[i],
                noise: &noises[i],
            })
            .collect();
        let got = batched.drafter_rollout_many(&reqs).unwrap();
        for (i, &k) in ks.iter().enumerate() {
            let want =
                serial.drafter_rollout(k, &xs[i], 58, &conds[i], &noises[i]).unwrap().unwrap();
            let (gs, gm) = got[i].as_ref().unwrap();
            assert_eq!(gs, &want.0, "request {i} samples");
            assert_eq!(gm, &want.1, "request {i} means");
        }
    }

    #[test]
    fn empty_wave_is_a_no_op() {
        let den = backend(24);
        assert!(den.drafter_rollout_many(&[]).unwrap().is_empty());
        assert_eq!(den.nfe().nfe(), 0.0);
        assert_eq!(den.arena_high_water(), 0);
    }

    #[test]
    fn engine_terminates_with_an_untrained_drafter() {
        // An untrained drafter is just a bad drafter: the engine must
        // still terminate losslessly (rejections correct by coupling).
        let den = backend(10);
        let cond = den.encode(&vec![0.25; OBS_DIM]).unwrap();
        let engine = SpecEngine::new();
        let mut rng = Rng::seed_from_u64(11);
        let mut trace = SegmentTrace::default();
        let seg = engine
            .generate_segment(&den, &cond, |_| SpecParams::fixed_k(8), &mut rng, &mut trace)
            .unwrap();
        assert_eq!(seg.len(), SEG);
        assert!(seg.iter().all(|v| v.is_finite()));
        assert!(trace.nfe > 0.0);
        // The mock's final deterministic target step lands on the
        // analytic clean action regardless of drafter quality.
        let clean = MockDenoiser::clean_action(&cond);
        let err =
            seg.iter().zip(&clean).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(err < 0.15, "max err {err}");
    }
}
