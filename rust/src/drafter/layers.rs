//! Dependency-free layer primitives for the drafter Transformer.
//!
//! The PPO scheduler's substrate (`scheduler::nn`) only needed plain MLP
//! layers; the drafter adds what a causal-attention block needs on top of
//! the same hand-rolled forward/backward style: [`LayerNorm`] with full
//! backprop, a free-function backward for the shared
//! [`crate::scheduler::nn::Linear`] layer (the MLP couples its backward
//! to the whole-net cache; attention needs per-layer control), and
//! sinusoidal timestep features. Everything is finite-difference checked
//! in the tests below — the same discipline `scheduler::nn` uses.

use crate::config::DIFFUSION_STEPS;
use crate::kernels::Kernels;
use crate::scheduler::nn::Linear;

/// Numerical floor inside LayerNorm's inverse standard deviation
/// (re-exported from the kernels layer, which owns the fused forward).
pub const LN_EPS: f32 = crate::kernels::LN_EPS;

/// Number of sinusoidal timestep features fed to the drafter.
pub const TIME_FEATS: usize = 8;

/// LayerNorm with learnable gain/bias over a fixed feature width.
#[derive(Debug, Clone)]
pub struct LayerNorm {
    /// Per-dimension gain γ.
    pub gamma: Vec<f32>,
    /// Per-dimension bias β.
    pub beta: Vec<f32>,
}

impl LayerNorm {
    /// Identity-initialized LayerNorm over `dim` features (γ = 1, β = 0).
    pub fn new(dim: usize) -> Self {
        Self { gamma: vec![1.0; dim], beta: vec![0.0; dim] }
    }

    /// y = γ·(x − μ)/√(σ² + ε) + β. Returns `(mean, rstd)`, which the
    /// backward pass needs alongside the raw input. Dispatched through
    /// the process-wide kernels handle; the original loop is preserved
    /// verbatim as the kernels layer's `Scalar` path.
    pub fn forward(&self, x: &[f32], y: &mut [f32]) -> (f32, f32) {
        debug_assert_eq!(x.len(), self.gamma.len());
        debug_assert_eq!(y.len(), self.gamma.len());
        Kernels::global().layernorm(&self.gamma, &self.beta, LN_EPS, x, y)
    }

    /// [`LayerNorm::forward`] with an explicit kernels handle (the
    /// serving drafter threads its own handle so a forced-path rollout
    /// never mixes arithmetic with the global path).
    pub fn forward_with(&self, kern: Kernels, x: &[f32], y: &mut [f32]) -> (f32, f32) {
        debug_assert_eq!(x.len(), self.gamma.len());
        debug_assert_eq!(y.len(), self.gamma.len());
        kern.layernorm(&self.gamma, &self.beta, LN_EPS, x, y)
    }

    /// Backward pass: accumulates dγ/dβ and **adds** dL/dx into `dx`
    /// (callers sum contributions from residual branches).
    #[allow(clippy::too_many_arguments)]
    pub fn backward(
        &self,
        x: &[f32],
        mean: f32,
        rstd: f32,
        dy: &[f32],
        dgamma: &mut [f32],
        dbeta: &mut [f32],
        dx: &mut [f32],
    ) {
        let n = x.len();
        let nf = n as f32;
        let mut m1 = 0.0f32; // mean of dxhat
        let mut m2 = 0.0f32; // mean of dxhat * xhat
        for i in 0..n {
            let xhat = (x[i] - mean) * rstd;
            let dxh = dy[i] * self.gamma[i];
            dgamma[i] += dy[i] * xhat;
            dbeta[i] += dy[i];
            m1 += dxh;
            m2 += dxh * xhat;
        }
        m1 /= nf;
        m2 /= nf;
        for i in 0..n {
            let xhat = (x[i] - mean) * rstd;
            let dxh = dy[i] * self.gamma[i];
            dx[i] += rstd * (dxh - m1 - xhat * m2);
        }
    }
}

/// Backward of `y = W x + b` for a shared [`Linear`]: accumulates dW/db
/// and (when `dx` is given) **adds** dL/dx into it. Routed through the
/// kernels layer's gradient primitives, which are reduction-free and
/// therefore bit-exact with the original loops on every kernel path.
pub fn linear_backward(
    l: &Linear,
    x: &[f32],
    dy: &[f32],
    dw: &mut [f32],
    db: &mut [f32],
    dx: Option<&mut [f32]>,
) {
    debug_assert_eq!(x.len(), l.in_dim);
    debug_assert_eq!(dy.len(), l.out_dim);
    let kern = Kernels::global();
    kern.outer_acc(x, dy, dw, db);
    if let Some(dx) = dx {
        kern.gemv_t_acc(&l.w, l.in_dim, l.out_dim, dy, dx);
    }
}

/// Numerically-stable in-place softmax over one attention row. Shared
/// by the training-side sequence forward and both serving rollout forms
/// (moved here verbatim from `drafter::model`) so the three can never
/// drift numerically.
pub fn softmax_inplace(scores: &mut [f32]) {
    let max = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let mut sum = 0.0f32;
    for s in scores.iter_mut() {
        *s = (*s - max).exp();
        sum += *s;
    }
    let inv = 1.0 / sum.max(1e-20);
    for s in scores.iter_mut() {
        *s *= inv;
    }
}

/// Sinusoidal features of a diffusion timestep: sin/cos pairs at
/// doubling frequencies of u = t/(T−1) — smooth, bounded in [−1, 1],
/// and distinct for every step of the schedule.
pub fn time_features(t: usize) -> [f32; TIME_FEATS] {
    let u = t as f32 / (DIFFUSION_STEPS - 1) as f32;
    let mut out = [0.0f32; TIME_FEATS];
    for i in 0..TIME_FEATS / 2 {
        let freq = (1usize << i) as f32 * std::f32::consts::PI;
        out[2 * i] = (freq * u).sin();
        out[2 * i + 1] = (freq * u).cos();
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::assert_close;
    use crate::util::Rng;

    #[test]
    fn layernorm_normalizes_before_gain() {
        let ln = LayerNorm::new(16);
        let mut rng = Rng::seed_from_u64(0);
        let x: Vec<f32> = rng.normal_vec(16).iter().map(|v| 3.0 * v + 2.0).collect();
        let mut y = vec![0.0; 16];
        ln.forward(&x, &mut y);
        let mean = y.iter().sum::<f32>() / 16.0;
        let var = y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 16.0;
        assert_close(mean, 0.0, 1e-5);
        assert_close(var, 1.0, 1e-3);
    }

    #[test]
    fn layernorm_backward_matches_finite_differences() {
        let dim = 8;
        let mut rng = Rng::seed_from_u64(1);
        let mut ln = LayerNorm::new(dim);
        for g in ln.gamma.iter_mut() {
            *g = 1.0 + 0.3 * rng.normal();
        }
        let x: Vec<f32> = rng.normal_vec(dim);
        let coef: Vec<f32> = rng.normal_vec(dim); // loss = Σ coef·y
        let loss = |ln: &LayerNorm, x: &[f32]| -> f32 {
            let mut y = vec![0.0; dim];
            ln.forward(x, &mut y);
            y.iter().zip(coef.iter()).map(|(a, b)| a * b).sum()
        };
        let mut y = vec![0.0; dim];
        let (mean, rstd) = ln.forward(&x, &mut y);
        let mut dgamma = vec![0.0; dim];
        let mut dbeta = vec![0.0; dim];
        let mut dx = vec![0.0; dim];
        ln.backward(&x, mean, rstd, &coef, &mut dgamma, &mut dbeta, &mut dx);
        let eps = 1e-3f32;
        for i in 0..dim {
            // dx
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&ln, &xp) - loss(&ln, &xm)) / (2.0 * eps);
            assert!(
                (fd - dx[i]).abs() < 2e-2 * fd.abs().max(dx[i].abs()).max(0.1),
                "dx[{i}]: fd {fd} vs analytic {}",
                dx[i]
            );
            // dgamma
            let orig = ln.gamma[i];
            ln.gamma[i] = orig + eps;
            let lp = loss(&ln, &x);
            ln.gamma[i] = orig - eps;
            let lm = loss(&ln, &x);
            ln.gamma[i] = orig;
            let fdg = (lp - lm) / (2.0 * eps);
            assert!(
                (fdg - dgamma[i]).abs() < 2e-2 * fdg.abs().max(dgamma[i].abs()).max(0.1),
                "dgamma[{i}]: fd {fdg} vs analytic {}",
                dgamma[i]
            );
            // dbeta = coef exactly
            assert_close(dbeta[i], coef[i], 1e-6);
        }
    }

    #[test]
    fn linear_backward_matches_finite_differences() {
        let mut rng = Rng::seed_from_u64(2);
        let mut l = Linear::init(5, 3, &mut rng);
        let x: Vec<f32> = rng.normal_vec(5);
        let coef: Vec<f32> = rng.normal_vec(3);
        let loss = |l: &Linear, x: &[f32]| -> f32 {
            let mut y = vec![0.0; 3];
            l.forward(x, &mut y);
            y.iter().zip(coef.iter()).map(|(a, b)| a * b).sum()
        };
        let mut dw = vec![0.0; 15];
        let mut db = vec![0.0; 3];
        let mut dx = vec![0.0; 5];
        linear_backward(&l, &x, &coef, &mut dw, &mut db, Some(&mut dx));
        let eps = 1e-3f32;
        for pi in [0usize, 7, 14] {
            let orig = l.w[pi];
            l.w[pi] = orig + eps;
            let lp = loss(&l, &x);
            l.w[pi] = orig - eps;
            let lm = loss(&l, &x);
            l.w[pi] = orig;
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - dw[pi]).abs() < 2e-2 * fd.abs().max(dw[pi].abs()).max(0.1),
                "dw[{pi}]: fd {fd} vs {}",
                dw[pi]
            );
        }
        for i in 0..5 {
            let mut xp = x.clone();
            xp[i] += eps;
            let mut xm = x.clone();
            xm[i] -= eps;
            let fd = (loss(&l, &xp) - loss(&l, &xm)) / (2.0 * eps);
            assert!(
                (fd - dx[i]).abs() < 2e-2 * fd.abs().max(dx[i].abs()).max(0.1),
                "dx[{i}]: fd {fd} vs {}",
                dx[i]
            );
        }
        for i in 0..3 {
            assert_close(db[i], coef[i], 1e-6);
        }
    }

    #[test]
    fn time_features_are_bounded_and_distinct() {
        let mut seen = std::collections::BTreeSet::new();
        for t in 0..DIFFUSION_STEPS {
            let f = time_features(t);
            for v in f {
                assert!(v.is_finite() && v.abs() <= 1.0 + 1e-6);
            }
            let key: Vec<u32> = f.iter().map(|v| v.to_bits()).collect();
            assert!(seen.insert(key), "timestep {t} collides with an earlier one");
        }
    }
}
