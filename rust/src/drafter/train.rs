//! In-crate drafter distillation (paper §3.1: "distill a Transformer-
//! based drafter to imitate the base model").
//!
//! The trainer rolls the **base target model** across the env fleet:
//! each trajectory resets/advances a real task env (receding-horizon,
//! like serving), runs full target-only reverse diffusion from its
//! observation, and records every step's `(x_t, t, cond, ε_target)`
//! tuple — stored in the x̂0 parametrization (`predict_x0` of the target
//! ε), which is the bounded, well-conditioned form of the same target
//! (see `drafter::model`).
//!
//! Training samples two kinds of batch items from those trajectories:
//!
//! * **single-token MSE** (sequence length 1) — the plain imitation loss
//!   matching `drafter_step` / the context-free first token of a round;
//! * **K-step rollout-consistency windows** — K consecutive denoising
//!   steps of one trajectory, teacher-forced through the causal
//!   attention, matching how the fused `drafter_rollout` is actually
//!   served (each step attends to the round's earlier steps).
//!
//! Both are MSE against the target's x̂0; `single_frac` sets the mix.

use crate::config::{
    DemoStyle, SpecParams, Task, ACT_DIM, DIFFUSION_STEPS, EXEC_STEPS, HORIZON, K_MAX,
};
use crate::diffusion::DdpmSchedule;
use crate::drafter::backend::DistilledDrafter;
use crate::drafter::model::{DrafterGrads, DrafterModel};
use crate::envs::make_env;
use crate::policy::Denoiser;
use crate::scheduler::adam::FlatAdam;
use crate::speculative::{SegmentTrace, SpecEngine};
use crate::util::Rng;
use anyhow::{ensure, Result};

/// Flattened segment size.
const SEG: usize = HORIZON * ACT_DIM;

/// One target-only denoising trajectory collected for distillation.
pub struct Trajectory {
    /// Conditioning vector of the env observation that produced it.
    pub cond: Vec<f32>,
    /// Latent inputs x_t, row-major steps×SEG, in rollout order
    /// (t descending from T−1 to 0).
    pub xs: Vec<f32>,
    /// Diffusion timesteps, descending (parallel to `xs` rows).
    pub ts: Vec<usize>,
    /// Distillation targets: the target model's x̂0 at each step,
    /// row-major steps×SEG.
    pub x0s: Vec<f32>,
}

/// Distillation hyperparameters.
#[derive(Debug, Clone)]
pub struct DistillConfig {
    /// Tasks whose envs feed conditioning (the env fleet slice).
    pub tasks: Vec<Task>,
    /// Demo style of those envs.
    pub style: DemoStyle,
    /// Denoising trajectories collected per task.
    pub trajectories_per_task: usize,
    /// Rollout-consistency window length K (clamped to [1, K_MAX]).
    pub window: usize,
    /// Optimizer steps.
    pub steps: usize,
    /// Batch items (windows) per optimizer step.
    pub batch: usize,
    /// Adam learning rate.
    pub lr: f32,
    /// Fraction of batch items trained as single tokens (pure MSE); the
    /// rest are K-step rollout-consistency windows.
    pub single_frac: f32,
    /// Base RNG seed (collection + training).
    pub seed: u64,
}

impl Default for DistillConfig {
    fn default() -> Self {
        Self {
            tasks: vec![Task::Lift, Task::PushT],
            style: DemoStyle::Ph,
            trajectories_per_task: 4,
            window: 8,
            steps: 400,
            batch: 8,
            lr: 3e-3,
            single_frac: 0.25,
            seed: 0,
        }
    }
}

/// Progress report passed to the training callback.
#[derive(Debug, Clone)]
pub struct DistillStats {
    /// Optimizer step (0-based).
    pub step: usize,
    /// Mean per-element x̂0 MSE of the step's batch.
    pub loss: f64,
}

/// Summary of one distillation run.
#[derive(Debug, Clone)]
pub struct DistillReport {
    /// Trajectories trained on.
    pub trajectories: usize,
    /// Optimizer steps taken.
    pub steps: usize,
    /// Mean batch loss of the final step.
    pub final_loss: f64,
}

/// Roll the base denoiser across the env fleet and record target-only
/// denoising trajectories. Each trajectory advances its env by the
/// denoised segment's first `EXEC_STEPS` actions (receding horizon), so
/// consecutive trajectories see the conditioning distribution the
/// serving path sees.
pub fn collect_trajectories(
    den: &dyn Denoiser,
    tasks: &[Task],
    style: DemoStyle,
    per_task: usize,
    seed: u64,
) -> Result<Vec<Trajectory>> {
    ensure!(!tasks.is_empty(), "distillation needs at least one task env");
    ensure!(per_task > 0, "distillation needs at least one trajectory per task");
    let sched = DdpmSchedule::cosine(DIFFUSION_STEPS);
    let mut out = Vec::with_capacity(tasks.len() * per_task);
    for (ti, &task) in tasks.iter().enumerate() {
        let mut env = make_env(task, style);
        let mut rng = Rng::seed_from_u64(seed ^ ((ti as u64 + 1) << 20));
        env.reset(&mut rng);
        for _ in 0..per_task {
            if env.done() {
                env.reset(&mut rng);
            }
            let cond = den.encode(&env.observe())?;
            let mut x = rng.normal_vec(SEG);
            let mut xs = Vec::with_capacity(DIFFUSION_STEPS * SEG);
            let mut ts = Vec::with_capacity(DIFFUSION_STEPS);
            let mut x0s = Vec::with_capacity(DIFFUSION_STEPS * SEG);
            let mut x0_target = vec![0.0f32; SEG];
            let mut x0_scratch = vec![0.0f32; SEG];
            let mut next = vec![0.0f32; SEG];
            let mut mean = vec![0.0f32; SEG];
            for t in (0..DIFFUSION_STEPS).rev() {
                let eps = den.target_step(&x, t, &cond)?;
                sched.predict_x0(t, &x, &eps, &mut x0_target);
                xs.extend_from_slice(&x);
                ts.push(t);
                x0s.extend_from_slice(&x0_target);
                let xi = rng.normal_vec(SEG);
                sched.step_into(t, &x, &eps, &xi, &mut x0_scratch, &mut next, &mut mean);
                x.copy_from_slice(&next);
            }
            out.push(Trajectory { cond, xs, ts, x0s });
            // Receding-horizon env advance with the denoised segment.
            for i in 0..EXEC_STEPS.min(HORIZON) {
                if env.done() {
                    break;
                }
                env.step(&x[i * ACT_DIM..(i + 1) * ACT_DIM]);
            }
        }
    }
    Ok(out)
}

/// Train a drafter on pre-collected trajectories. `init` continues
/// training an existing model (fresh optimizer state) or `None` starts
/// from a Xavier init.
pub fn train_on(
    trajs: &[Trajectory],
    cfg: &DistillConfig,
    init: Option<DrafterModel>,
    mut progress: impl FnMut(&DistillStats),
) -> Result<(DrafterModel, DistillReport)> {
    ensure!(!trajs.is_empty(), "no distillation trajectories");
    ensure!(cfg.steps > 0, "distillation needs at least one optimizer step");
    let window = cfg.window.clamp(1, K_MAX);
    let batch = cfg.batch.max(1);
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xd1af_7e41);
    let mut model = match init {
        Some(m) => m,
        None => DrafterModel::init(&mut rng),
    };
    let mut adam = FlatAdam::new(model.n_params(), cfg.lr);
    let mut grads = DrafterGrads::zeros(&model);
    let mut last_loss = f64::NAN;
    for step in 0..cfg.steps {
        grads.clear();
        let mut loss_sum = 0.0f64;
        for _ in 0..batch {
            let traj = &trajs[rng.below(trajs.len())];
            let n = traj.ts.len();
            let l = if rng.uniform() < cfg.single_frac { 1 } else { window.min(n) };
            let s = rng.below(n - l + 1);
            let xs = &traj.xs[s * SEG..(s + l) * SEG];
            let ts = &traj.ts[s..s + l];
            let target = &traj.x0s[s * SEG..(s + l) * SEG];
            let (ys, cache) = model.forward_seq(xs, ts, &traj.cond);
            let mut dys = vec![0.0f32; l * SEG];
            let inv = 1.0 / (l * SEG) as f32;
            let mut item_loss = 0.0f64;
            for i in 0..l * SEG {
                let d = ys[i] - target[i];
                item_loss += (d as f64) * (d as f64);
                dys[i] = 2.0 * d * inv;
            }
            loss_sum += item_loss / (l * SEG) as f64;
            model.backward_seq(&cache, &dys, &mut grads);
        }
        grads.scale(1.0 / batch as f32);
        let mut flat = model.flatten();
        adam.step(&mut flat, &grads.flatten());
        model.unflatten(&flat);
        last_loss = loss_sum / batch as f64;
        if step % 50 == 0 || step + 1 == cfg.steps {
            progress(&DistillStats { step, loss: last_loss });
        }
    }
    let report =
        DistillReport { trajectories: trajs.len(), steps: cfg.steps, final_loss: last_loss };
    Ok((model, report))
}

/// Full pipeline: collect trajectories from the base denoiser, then
/// train a fresh drafter on them.
pub fn distill(
    den: &dyn Denoiser,
    cfg: &DistillConfig,
    progress: impl FnMut(&DistillStats),
) -> Result<(DrafterModel, DistillReport)> {
    let trajs =
        collect_trajectories(den, &cfg.tasks, cfg.style, cfg.trajectories_per_task, cfg.seed)?;
    train_on(&trajs, cfg, None, progress)
}

/// Acceptance measured by actually serving: speculative segments over
/// fresh env rollouts.
#[derive(Debug, Clone)]
pub struct AcceptReport {
    /// Accepted drafts / proposed drafts across all segments.
    pub accept_rate: f64,
    /// Mean NFE per segment.
    pub mean_nfe: f64,
    /// Segments generated.
    pub segments: usize,
}

/// Run the speculative engine against `den` over env-driven conditioning
/// and report the measured draft accept rate and NFE — the quality
/// metric the drafter is distilled for (drafter quality bounds accept
/// rate, which bounds speedup).
pub fn accept_stats(
    den: &dyn Denoiser,
    tasks: &[Task],
    style: DemoStyle,
    segments_per_task: usize,
    params: SpecParams,
    seed: u64,
) -> Result<AcceptReport> {
    ensure!(!tasks.is_empty(), "accept_stats needs at least one task");
    let engine = SpecEngine::new();
    let mut drafts = 0usize;
    let mut accepted = 0usize;
    let mut nfe = 0.0f64;
    let mut segments = 0usize;
    for (ti, &task) in tasks.iter().enumerate() {
        let mut env = make_env(task, style);
        let mut rng = Rng::seed_from_u64(seed ^ ((ti as u64 + 1) << 18));
        env.reset(&mut rng);
        for _ in 0..segments_per_task {
            if env.done() {
                env.reset(&mut rng);
            }
            let cond = den.encode(&env.observe())?;
            let mut trace = SegmentTrace::default();
            let seg = engine.generate_segment(den, &cond, |_| params, &mut rng, &mut trace)?;
            drafts += trace.drafts();
            accepted += trace.accepted();
            nfe += trace.nfe;
            segments += 1;
            for i in 0..EXEC_STEPS.min(HORIZON) {
                if env.done() {
                    break;
                }
                env.step(&seg[i * ACT_DIM..(i + 1) * ACT_DIM]);
            }
        }
    }
    Ok(AcceptReport {
        accept_rate: if drafts == 0 { 0.0 } else { accepted as f64 / drafts as f64 },
        mean_nfe: nfe / segments.max(1) as f64,
        segments,
    })
}

/// Accept-rate scorecard: the same engine measurement over an untrained
/// drafter and over `model`, each wrapped around its own base backend.
/// Returns `(untrained, distilled)` reports; the CLI and the example go
/// through this so their before/after numbers stay comparable.
#[allow(clippy::too_many_arguments)]
pub fn accept_scorecard(
    untrained_base: Box<dyn Denoiser>,
    trained_base: Box<dyn Denoiser>,
    model: &DrafterModel,
    tasks: &[Task],
    style: DemoStyle,
    segments_per_task: usize,
    params: SpecParams,
    seed: u64,
) -> Result<(AcceptReport, AcceptReport)> {
    let untrained = DistilledDrafter::new(
        untrained_base,
        DrafterModel::init(&mut Rng::seed_from_u64(seed ^ 0xbade)),
    );
    let before = accept_stats(&untrained, tasks, style, segments_per_task, params, seed)?;
    let distilled = DistilledDrafter::new(trained_base, model.clone());
    let after = accept_stats(&distilled, tasks, style, segments_per_task, params, seed)?;
    Ok((before, after))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::policy::mock::MockDenoiser;

    #[test]
    fn trajectories_cover_the_schedule_in_rollout_order() {
        let den = MockDenoiser::with_bias(0.0);
        let trajs =
            collect_trajectories(&den, &[Task::Lift], DemoStyle::Ph, 2, 0).unwrap();
        assert_eq!(trajs.len(), 2);
        for tr in &trajs {
            assert_eq!(tr.ts.len(), DIFFUSION_STEPS);
            assert_eq!(tr.xs.len(), DIFFUSION_STEPS * SEG);
            assert_eq!(tr.x0s.len(), DIFFUSION_STEPS * SEG);
            assert_eq!(tr.ts[0], DIFFUSION_STEPS - 1);
            for w in tr.ts.windows(2) {
                assert_eq!(w[0], w[1] + 1, "timesteps must descend by 1");
            }
            // x̂0 targets live in the clipped sample range.
            for v in &tr.x0s {
                assert!(v.is_finite() && v.abs() <= 1.0 + 1e-6);
            }
        }
        // For the mock target the x̂0 target is the analytic clean action.
        let clean = MockDenoiser::clean_action(&trajs[0].cond);
        let last_row = &trajs[0].x0s[(DIFFUSION_STEPS - 1) * SEG..];
        for i in 0..SEG {
            assert!((last_row[i] - clean[i]).abs() < 2e-2, "x0 target drifted at {i}");
        }
    }

    #[test]
    fn short_training_run_reduces_loss() {
        let den = MockDenoiser::with_bias(0.0);
        let cfg = DistillConfig {
            tasks: vec![Task::Lift],
            trajectories_per_task: 2,
            window: 4,
            steps: 60,
            batch: 4,
            ..Default::default()
        };
        let mut first = f64::NAN;
        let mut last = f64::NAN;
        let (_, report) = distill(&den, &cfg, |s| {
            if s.step == 0 {
                first = s.loss;
            }
            last = s.loss;
        })
        .unwrap();
        assert!(first.is_finite() && last.is_finite());
        assert!(last < first, "loss must drop: first {first} last {last}");
        assert!((report.final_loss - last).abs() < 1e-12);
        assert_eq!(report.trajectories, 2);
    }

    #[test]
    fn continuing_training_from_a_model_is_supported() {
        let den = MockDenoiser::with_bias(0.0);
        let trajs =
            collect_trajectories(&den, &[Task::Lift], DemoStyle::Ph, 1, 3).unwrap();
        let cfg = DistillConfig { steps: 5, batch: 2, window: 3, ..Default::default() };
        let (m1, _) = train_on(&trajs, &cfg, None, |_| {}).unwrap();
        let flat1 = m1.flatten();
        let (m2, _) = train_on(&trajs, &cfg, Some(m1), |_| {}).unwrap();
        assert_ne!(flat1, m2.flatten(), "continued training must move the weights");
    }

    #[test]
    fn accept_stats_runs_the_engine_on_env_conditioning() {
        // The mock's own drafter pair with zero bias accepts ~everything.
        let den = MockDenoiser::with_bias(0.0);
        let report = accept_stats(
            &den,
            &[Task::Lift, Task::PushT],
            DemoStyle::Ph,
            1,
            SpecParams::fixed_k(8),
            0,
        )
        .unwrap();
        assert_eq!(report.segments, 2);
        assert!(report.accept_rate > 0.95, "rate {}", report.accept_rate);
        assert!(report.mean_nfe < 50.0, "nfe {}", report.mean_nfe);
    }
}
