//! The TS-DP speculative decoding engine (paper §3.2, Algorithm 1).
//!
//! One *round* at diffusion level t:
//!
//! 1. **Draft** — the drafter rolls out k = K(t) serial denoising steps
//!    from the current latent, recording each sample, its posterior mean
//!    μ̂_j, and the noise draw ξ_j (k/8 NFE). Uses the fused rollout
//!    artifact when one exists for k, else serial drafter calls.
//! 2. **Verify** — the target evaluates all k draft *input* states in a
//!    single batched forward pass (1 NFE) giving target means μ_j.
//! 3. **Accept** — scan drafts in order with the MH test (Eq. 10–11,
//!    σ widened by the scheduler's sigma_scale, threshold λ); commit the
//!    accepted prefix; correct the first rejection by reflection-maximal
//!    coupling (Eq. 4–6) so the committed sample is exactly
//!    target-distributed — no extra target call.
//!
//! Rounds repeat until t = 0; the final step is a single target call.
//!
//! The round logic itself lives in [`crate::speculative::job::SegmentJob`],
//! a resumable state machine; `generate_segment` here is the thin
//! single-job driver (used by the baselines table, the PPO trainer, and
//! tests), while the serving coordinator drives many jobs concurrently
//! and fuses their verify stages across requests.

use crate::config::{SpecParams, ACT_DIM, DIFFUSION_STEPS, HORIZON};
use crate::diffusion::DdpmSchedule;
use crate::policy::Denoiser;
use crate::speculative::job::{SegmentJob, Stage};
use crate::speculative::trace::SegmentTrace;
use crate::util::Rng;
use anyhow::Result;

/// Flattened segment size.
pub const SEG: usize = HORIZON * ACT_DIM;

/// Speculative decoding engine over any [`Denoiser`].
pub struct SpecEngine {
    sched: DdpmSchedule,
    /// Use the classic stochastic accept test (U ≤ p) instead of the
    /// paper's deterministic threshold p ≥ λ. Ablation knob: the
    /// stochastic test is the textbook lossless rule; the threshold is
    /// what the scheduler tunes (§3.2).
    pub stochastic_accept: bool,
}

impl Default for SpecEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl SpecEngine {
    /// Engine with the standard cosine schedule.
    pub fn new() -> Self {
        Self { sched: DdpmSchedule::cosine(DIFFUSION_STEPS), stochastic_accept: false }
    }

    /// Engine using the classic stochastic acceptance test (ablation).
    pub fn stochastic() -> Self {
        Self { stochastic_accept: true, ..Self::new() }
    }

    /// Borrow the schedule (shared with baselines / tests).
    pub fn schedule(&self) -> &DdpmSchedule {
        &self.sched
    }

    /// Start a resumable job for one segment (the serving engine's entry
    /// point; draws the initial latent from `rng`).
    pub fn start_job(&self, cond: Vec<f32>, rng: &mut Rng) -> SegmentJob<'_> {
        SegmentJob::new(&self.sched, self.stochastic_accept, cond, rng)
    }

    /// Generate one action segment by speculative denoising.
    ///
    /// `params` may be updated per-round by the scheduler through
    /// `param_fn` (passed the current timestep); pass `|_| params` for
    /// fixed parameters.
    ///
    /// This drives a single [`SegmentJob`] to completion and is
    /// bit-identical to the coordinator's micro-batched path for the same
    /// per-request RNG stream.
    pub fn generate_segment(
        &self,
        den: &dyn Denoiser,
        cond: &[f32],
        mut param_fn: impl FnMut(usize) -> SpecParams,
        rng: &mut Rng,
        trace: &mut SegmentTrace,
    ) -> Result<Vec<f32>> {
        let start = std::time::Instant::now();
        let mut job = self.start_job(cond.to_vec(), rng);
        loop {
            match job.stage() {
                Stage::Draft => {
                    let params = param_fn(job.t());
                    job.draft(den, params, rng)?;
                }
                // draft() runs begin/rollout/finish atomically, so the
                // solo driver never parks a job mid-wave.
                Stage::DraftWave => unreachable!("draft() is atomic"),
                Stage::Verify => {
                    let eps = den.target_verify(job.verify_xs(), job.verify_ts(), cond)?;
                    job.accept(&eps, rng);
                }
                Stage::Final => job.finalize(den)?,
                Stage::Done => break,
            }
        }
        let shard = job.shard();
        let (segment, rounds, nfe) = job.into_parts();
        trace.rounds.extend(rounds);
        trace.nfe = nfe;
        trace.wall_secs = start.elapsed().as_secs_f64();
        trace.shard = shard;
        Ok(segment)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OBS_DIM;
    use crate::policy::mock::MockDenoiser;

    fn gen(bias: f32, params: SpecParams, seed: u64) -> (Vec<f32>, SegmentTrace, f64) {
        let m = MockDenoiser::with_bias(bias);
        let cond = Denoiser::encode(&m, &vec![0.25; OBS_DIM]).unwrap();
        let engine = SpecEngine::new();
        let mut rng = Rng::seed_from_u64(seed);
        let mut trace = SegmentTrace::default();
        let seg = engine
            .generate_segment(&m, &cond, |_| params, &mut rng, &mut trace)
            .unwrap();
        let nfe = trace.nfe;
        (seg, trace, nfe)
    }

    #[test]
    fn perfect_drafter_accepts_everything() {
        let (_, trace, _) = gen(0.0, SpecParams::fixed_k(8), 0);
        assert!(trace.acceptance_rate() > 0.999, "rate {}", trace.acceptance_rate());
    }

    #[test]
    fn hopeless_drafter_rejects_mostly_but_still_terminates() {
        // Note: even an absurdly-biased drafter is accepted at very high
        // noise levels (the posterior mean barely depends on x̂0 there and
        // x̂0 is clipped), so the floor is not exactly 0 — this matches
        // the paper's Fig. 3a phase structure. Use a strict λ and no σ
        // widening to make rejection bite.
        let mut p = SpecParams::fixed_k(8);
        p.lambda = 0.5;
        p.sigma_scale = 1.0;
        let (seg, trace, nfe) = gen(100.0, p, 1);
        assert!(trace.acceptance_rate() < 0.15, "rate {}", trace.acceptance_rate());
        assert_eq!(seg.len(), SEG);
        // Rejection-dominated: NFE worse than vanilla (verification pays
        // for nothing), and rejected rounds commit exactly 1 step via a
        // reflected (not coupled) correction.
        assert!(nfe > 100.0, "nfe {nfe}");
        let reflected = trace.rounds.iter().filter(|r| r.coupled == Some(false)).count();
        assert!(reflected > trace.rounds.len() / 2);
    }

    #[test]
    fn nfe_is_far_below_vanilla_for_good_drafter() {
        let (_, _, nfe) = gen(0.0, SpecParams::fixed_k(16), 2);
        // Vanilla = 100 NFE. Perfect drafter with K=16:
        // ceil(99/16) rounds x (1 + 16/8) + final ~ 22 NFE.
        assert!(nfe < 35.0, "nfe {nfe}");
    }

    #[test]
    fn rounds_cover_all_timesteps_exactly() {
        let (_, trace, _) = gen(0.05, SpecParams::fixed_k(10), 3);
        let total: usize = trace.rounds.iter().map(|r| r.committed).sum();
        assert_eq!(total, DIFFUSION_STEPS - 1, "rounds must cover t=99..1");
        // Rounds are contiguous: t_start decreases by committed.
        let mut t = DIFFUSION_STEPS - 1;
        for r in &trace.rounds {
            assert_eq!(r.t_start, t);
            t -= r.committed;
        }
        assert_eq!(t, 0);
    }

    #[test]
    fn losslessness_segment_distribution_matches_vanilla() {
        // With a *perfect* drafter the speculative segment must have the
        // same distribution as vanilla DP. Both converge to the mock's
        // clean action, so compare against that analytic ground truth.
        let m = MockDenoiser::with_bias(0.0);
        let cond = Denoiser::encode(&m, &vec![0.4; OBS_DIM]).unwrap();
        let clean = MockDenoiser::clean_action(&cond);
        let (seg, _, _) = gen(0.0, SpecParams::fixed_k(12), 4);
        let max_err =
            seg.iter().zip(&clean).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        assert!(max_err < 0.15, "max err {max_err}");
    }

    #[test]
    fn moderate_bias_gives_intermediate_acceptance() {
        let mut p = SpecParams::fixed_k(8);
        p.lambda = 0.3;
        p.sigma_scale = 1.0;
        let (_, trace, nfe) = gen(0.35, p, 5);
        let rate = trace.acceptance_rate();
        assert!(rate > 0.2 && rate < 0.9, "rate {rate}");
        assert!(nfe < 100.0, "still cheaper than vanilla: {nfe}");
    }

    #[test]
    fn lambda_one_rejects_imperfect_drafts() {
        let mut p = SpecParams::fixed_k(8);
        p.lambda = 1.0;
        let (_, trace, _) = gen(0.2, p, 6);
        assert!(trace.acceptance_rate() < 0.2, "rate {}", trace.acceptance_rate());
    }

    #[test]
    fn sigma_scale_rescues_acceptance() {
        let mut narrow = SpecParams::fixed_k(8);
        narrow.sigma_scale = 0.5;
        let mut wide = SpecParams::fixed_k(8);
        wide.sigma_scale = 8.0;
        let (_, tr_narrow, _) = gen(0.3, narrow, 7);
        let (_, tr_wide, _) = gen(0.3, wide, 7);
        assert!(
            tr_wide.acceptance_rate() > tr_narrow.acceptance_rate(),
            "{} vs {}",
            tr_wide.acceptance_rate(),
            tr_narrow.acceptance_rate()
        );
    }

    #[test]
    fn stage_dependent_k_is_respected() {
        let params = SpecParams {
            stages: crate::config::StageParams { k_early: 2, k_mid: 9, k_late: 3 },
            lambda: 0.05,
            sigma_scale: 2.0,
        };
        let (_, trace, _) = gen(0.0, params, 8);
        for r in &trace.rounds {
            let expect = params.stages.k_for_timestep(r.t_start).min(r.t_start);
            assert_eq!(r.k, expect, "round at t={}", r.t_start);
        }
    }

    #[test]
    fn stochastic_accept_mode_is_lossless_and_less_permissive() {
        // Classic U <= p acceptance: rejects with prob 1-p even above the
        // threshold, so acceptance <= the permissive-threshold variant.
        let m = MockDenoiser::with_bias(0.2);
        let cond = Denoiser::encode(&m, &vec![0.25; OBS_DIM]).unwrap();
        let run = |engine: SpecEngine, seed| {
            let mut rng = Rng::seed_from_u64(seed);
            let mut tr = SegmentTrace::default();
            let p = SpecParams::fixed_k(8);
            engine.generate_segment(&m, &cond, |_| p, &mut rng, &mut tr).unwrap();
            tr.acceptance_rate()
        };
        let det = run(SpecEngine::new(), 9);
        let sto = run(SpecEngine::stochastic(), 9);
        assert!(sto <= det + 0.05, "stochastic {sto} vs threshold {det}");
    }

    #[test]
    fn deterministic_given_seed() {
        let (a, _, _) = gen(0.1, SpecParams::fixed_k(8), 42);
        let (b, _, _) = gen(0.1, SpecParams::fixed_k(8), 42);
        assert_eq!(a, b);
    }
}
