//! Per-round / per-segment records of the speculative decoding process.
//!
//! The bench harness reads these to regenerate the paper's figures
//! (Fig. 3: acceptance vs timestep, Fig. 4: accepted drafts vs velocity,
//! Fig. 5: scheduled parameters over time, Fig. 6: acceptance/draft count
//! with vs without the scheduler) and the supplement's draft-count /
//! acceptance-rate tables.

use crate::config::SpecParams;

/// One speculative round (draft rollout + batched verification).
#[derive(Debug, Clone)]
pub struct RoundRecord {
    /// Diffusion timestep the round started at.
    pub t_start: usize,
    /// Number of drafts rolled out.
    pub k: usize,
    /// Drafts accepted (prefix length before first rejection).
    pub accepted: usize,
    /// Timesteps advanced (accepted + 1 if a rejection was corrected).
    pub committed: usize,
    /// MH acceptance probability of each draft, in rollout order.
    pub probs: Vec<f64>,
    /// Whether the corrected sample coupled (kept the draft) rather than
    /// reflected; None when every draft was accepted.
    pub coupled: Option<bool>,
    /// Speculative parameters in force during the round.
    pub params: SpecParams,
}

/// Full record of one action-segment generation.
#[derive(Debug, Clone, Default)]
pub struct SegmentTrace {
    /// All speculative rounds, in order.
    pub rounds: Vec<RoundRecord>,
    /// NFE consumed by this segment.
    pub nfe: f64,
    /// Wall-clock seconds for this segment.
    pub wall_secs: f64,
    /// Shard that served the segment (0 outside the sharded coordinator;
    /// placement is observability only — served bits never depend on it).
    pub shard: usize,
}

impl SegmentTrace {
    /// Total drafts proposed.
    pub fn drafts(&self) -> usize {
        self.rounds.iter().map(|r| r.k).sum()
    }

    /// Total drafts accepted.
    pub fn accepted(&self) -> usize {
        self.rounds.iter().map(|r| r.accepted).sum()
    }

    /// Draft acceptance rate in [0, 1] (0 when no drafts were proposed).
    pub fn acceptance_rate(&self) -> f64 {
        let d = self.drafts();
        if d == 0 {
            0.0
        } else {
            self.accepted() as f64 / d as f64
        }
    }

    /// Mean acceptance probability at a given diffusion timestep across
    /// rounds (Fig. 3 series). Returns None if the timestep was never
    /// drafted.
    pub fn acceptance_prob_at(&self, t: usize) -> Option<f64> {
        let mut sum = 0.0;
        let mut n = 0usize;
        for r in &self.rounds {
            for (j, p) in r.probs.iter().enumerate() {
                if r.t_start - j == t {
                    sum += p;
                    n += 1;
                }
            }
        }
        if n == 0 {
            None
        } else {
            Some(sum / n as f64)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(t: usize, k: usize, accepted: usize) -> RoundRecord {
        RoundRecord {
            t_start: t,
            k,
            accepted,
            committed: accepted + 1,
            probs: vec![0.9; k],
            coupled: Some(false),
            params: SpecParams::default(),
        }
    }

    #[test]
    fn rates_aggregate_over_rounds() {
        let mut tr = SegmentTrace::default();
        tr.rounds.push(round(99, 10, 8));
        tr.rounds.push(round(90, 10, 10));
        assert_eq!(tr.drafts(), 20);
        assert_eq!(tr.accepted(), 18);
        assert!((tr.acceptance_rate() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_has_zero_rate() {
        let tr = SegmentTrace::default();
        assert_eq!(tr.acceptance_rate(), 0.0);
        assert_eq!(tr.acceptance_prob_at(50), None);
    }

    #[test]
    fn acceptance_prob_at_maps_timesteps() {
        let mut tr = SegmentTrace::default();
        let mut r = round(99, 3, 3);
        r.probs = vec![0.5, 0.7, 0.9];
        tr.rounds.push(r);
        assert_eq!(tr.acceptance_prob_at(99), Some(0.5));
        assert_eq!(tr.acceptance_prob_at(98), Some(0.7));
        assert_eq!(tr.acceptance_prob_at(97), Some(0.9));
        assert_eq!(tr.acceptance_prob_at(96), None);
    }
}
