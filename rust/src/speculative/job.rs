//! Resumable per-request speculative state machine.
//!
//! [`SegmentJob`] decomposes one action-segment generation into explicit
//! Draft → Verify → Accept stages so a serving engine can hold many jobs
//! in flight and *fuse their verify stages* into one multi-request target
//! forward (`Denoiser::target_verify_many`). The single-request driver
//! ([`crate::speculative::SpecEngine::generate_segment`]) runs the same
//! state machine to completion one stage at a time, so the two paths are
//! bit-identical for a fixed per-request RNG stream — batching never
//! changes results, only wall-clock.
//!
//! The job owns preallocated scratch buffers for latents, draft samples,
//! posterior means, and noise: the accept scan performs **zero heap
//! allocations per draft** (see `benches/speculative.rs` for the measured
//! delta vs the per-draft `vec![0.0; SEG]` churn it replaced).
//!
//! **Migration contract.** A `SegmentJob` itself never crosses shards:
//! under an elastic fleet ([`crate::coordinator::fleet`]) a session
//! moves only at request boundaries, when it has no job in flight. The
//! state that migrates is exactly the session's RNG and generator
//! (wrapped in a `SessionSnapshot`); every draw a job consumes comes
//! from that RNG in [`SegmentJob::begin_draft`], before wave batching
//! groups jobs — which is why moving the RNG between shards preserves
//! bit-identity without the job ever being serialized.

use crate::config::{SpecParams, DIFFUSION_STEPS, DRAFTER_NFE, K_MAX, VERIFY_BATCH};
use crate::diffusion::{acceptance, coupling, DdpmSchedule};
use crate::policy::Denoiser;
use crate::speculative::engine::SEG;
use crate::speculative::trace::RoundRecord;
use crate::util::Rng;
use anyhow::Result;

/// Where a job is in its current speculative round.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Next action: roll out the drafter for one round.
    Draft,
    /// Round begun and noise drawn ([`SegmentJob::begin_draft`]);
    /// waiting for the (possibly wave-batched) drafter rollout. The
    /// coordinator fuses every job parked here into one
    /// `Denoiser::drafter_rollout_many` call; the solo engine driver
    /// never observes this stage ([`SegmentJob::draft`] is atomic).
    DraftWave,
    /// Draft done; waiting for the (possibly fused) verify forward pass.
    Verify,
    /// t = 0 reached; needs the final deterministic target step.
    Final,
    /// Segment complete; output ready.
    Done,
}

/// One in-flight segment generation, resumable stage by stage.
pub struct SegmentJob<'s> {
    sched: &'s DdpmSchedule,
    stochastic_accept: bool,
    /// Shard worker driving this job (trace plumbing; 0 outside the
    /// sharded coordinator).
    shard: usize,
    cond: Vec<f32>,
    /// Current latent x_t.
    x: Vec<f32>,
    /// Current diffusion level (counts down to 0).
    t: usize,
    stage: Stage,

    // --- per-round state (valid between draft() and accept()) ---
    /// Drafts rolled out this round.
    k: usize,
    /// Diffusion level at the start of the current round.
    round_t: usize,
    /// Clamped parameters in force this round.
    params: SpecParams,
    /// Noise draws ξ_j, k × SEG (reused across rounds).
    noise: Vec<f32>,
    /// Draft *input* states, k × SEG (states[0] = x at round start).
    states: Vec<f32>,
    /// Draft samples, k × SEG.
    samples: Vec<f32>,
    /// Drafter posterior means μ̂_j, k × SEG.
    means: Vec<f32>,
    /// Padded verify inputs (VERIFY_BATCH × SEG) for the fused forward.
    verify_xs: Vec<f32>,
    /// Padded verify timesteps (VERIFY_BATCH).
    verify_ts: Vec<f32>,
    /// Accept-scan scratch: predicted x̂0.
    x0_scratch: Vec<f32>,
    /// Accept-scan scratch: target posterior mean μ_t.
    mu_scratch: Vec<f32>,

    // --- accumulated outputs ---
    rounds: Vec<RoundRecord>,
    nfe: f64,
    output: Vec<f32>,
}

impl<'s> SegmentJob<'s> {
    /// Start a job: draws the initial latent from `rng` (the first draw
    /// of the per-request stream, exactly as the monolithic loop did).
    pub fn new(
        sched: &'s DdpmSchedule,
        stochastic_accept: bool,
        cond: Vec<f32>,
        rng: &mut Rng,
    ) -> Self {
        let x = rng.normal_vec(SEG);
        let t = DIFFUSION_STEPS - 1;
        Self {
            sched,
            stochastic_accept,
            shard: 0,
            cond,
            x,
            t,
            stage: if t == 0 { Stage::Final } else { Stage::Draft },
            k: 0,
            round_t: t,
            params: SpecParams::fixed_default(),
            noise: Vec::with_capacity(K_MAX * SEG),
            states: Vec::with_capacity(K_MAX * SEG),
            samples: Vec::with_capacity(K_MAX * SEG),
            means: Vec::with_capacity(K_MAX * SEG),
            verify_xs: Vec::with_capacity(VERIFY_BATCH * SEG),
            verify_ts: Vec::with_capacity(VERIFY_BATCH),
            x0_scratch: vec![0.0; SEG],
            mu_scratch: vec![0.0; SEG],
            rounds: Vec::new(),
            nfe: 0.0,
            output: Vec::new(),
        }
    }

    /// Current stage.
    pub fn stage(&self) -> Stage {
        self.stage
    }

    /// Label the job with the shard worker that owns it (recorded into
    /// the segment trace; never affects generation).
    pub fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
    }

    /// Shard worker driving this job.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Current diffusion level.
    pub fn t(&self) -> usize {
        self.t
    }

    /// The in-progress plan latent (flat HORIZON×ACT_DIM), partially
    /// denoised to level [`Self::t`]. Read-only: streamed to clients
    /// after each committed round as an anytime plan (Real-Time
    /// Iteration style), becoming the finished segment at t = 0.
    pub fn plan(&self) -> &[f32] {
        &self.x
    }

    /// Conditioning vector (one per request; the fused verify concatenates
    /// these across jobs).
    pub fn cond(&self) -> &[f32] {
        &self.cond
    }

    /// Padded verify candidates (valid in [`Stage::Verify`]).
    pub fn verify_xs(&self) -> &[f32] {
        &self.verify_xs
    }

    /// Padded verify timesteps (valid in [`Stage::Verify`]).
    pub fn verify_ts(&self) -> &[f32] {
        &self.verify_ts
    }

    /// NFE consumed so far (drafter steps at 1/8, verify and final target
    /// forwards at 1 — identical to the paper's per-request accounting
    /// regardless of how many requests share a fused verify call).
    pub fn nfe(&self) -> f64 {
        self.nfe
    }

    /// Rounds recorded so far.
    pub fn rounds(&self) -> &[RoundRecord] {
        &self.rounds
    }

    /// Stage 1 — draft rollout for one round at the current level.
    ///
    /// `params` is clamped here (as the monolithic loop did per round).
    /// Consumes exactly k×SEG normal draws from `rng`. Atomic
    /// composition of [`Self::begin_draft`] → rollout →
    /// [`Self::finish_draft`], so solo drivers never observe
    /// [`Stage::DraftWave`].
    pub fn draft(&mut self, den: &dyn Denoiser, params: SpecParams, rng: &mut Rng) -> Result<()> {
        self.begin_draft(params, rng);
        let rollout =
            den.drafter_rollout(self.k, &self.x, self.round_t, &self.cond, &self.noise)?;
        self.finish_draft(den, rollout)
    }

    /// Stage 1a — open a draft round: clamp `params`, pick k, and draw
    /// the round's noise from the *session's own* RNG stream (same draw
    /// order as the monolithic [`Self::draft`]). Parks the job in
    /// [`Stage::DraftWave`] so a coordinator can fuse its rollout with
    /// other jobs' — all randomness is consumed here, before the wave
    /// forms, which is why wave composition can never change this job's
    /// bits.
    pub fn begin_draft(&mut self, params: SpecParams, rng: &mut Rng) {
        debug_assert_eq!(self.stage, Stage::Draft);
        let params = params.clamped();
        let t = self.t;
        let k = params.stages.k_for_timestep(t).min(t);
        debug_assert!(k >= 1 && k <= t);
        self.k = k;
        self.round_t = t;
        self.params = params;

        // Noise draws for the round (same draw order as `normal_vec`).
        self.noise.clear();
        for _ in 0..k * SEG {
            self.noise.push(rng.normal());
        }
        self.stage = Stage::DraftWave;
    }

    /// This round's rollout request (valid in [`Stage::DraftWave`]):
    /// what the coordinator hands to `Denoiser::drafter_rollout_many`.
    pub fn rollout_request(&self) -> crate::policy::RolloutRequest<'_> {
        debug_assert_eq!(self.stage, Stage::DraftWave);
        crate::policy::RolloutRequest {
            k: self.k,
            x: &self.x,
            t0: self.round_t,
            cond: &self.cond,
            noise: &self.noise,
        }
    }

    /// Stage 1b — install this round's rollout result (`None` falls
    /// back to serial drafter steps, bit-identical to the fused path's
    /// contract) and build the padded verify batch. Identical arithmetic
    /// to the monolithic [`Self::draft`] tail.
    pub fn finish_draft(
        &mut self,
        den: &dyn Denoiser,
        rollout: Option<(Vec<f32>, Vec<f32>)>,
    ) -> Result<()> {
        debug_assert_eq!(self.stage, Stage::DraftWave);
        let (t, k) = (self.round_t, self.k);

        // Rollout: fused result when available, else serial drafter
        // steps written straight into the reused sample/mean buffers.
        match rollout {
            Some((samples, means)) => {
                debug_assert_eq!(samples.len(), k * SEG);
                debug_assert_eq!(means.len(), k * SEG);
                self.samples = samples;
                self.means = means;
            }
            None => {
                self.samples.clear();
                self.samples.resize(k * SEG, 0.0);
                self.means.clear();
                self.means.resize(k * SEG, 0.0);
                let sched = self.sched;
                for j in 0..k {
                    let tj = t - j;
                    let eps = {
                        let cur: &[f32] = if j == 0 {
                            &self.x
                        } else {
                            &self.samples[(j - 1) * SEG..j * SEG]
                        };
                        den.drafter_step(cur, tj, &self.cond)?
                    };
                    let xi = &self.noise[j * SEG..(j + 1) * SEG];
                    let (head, tail) = self.samples.split_at_mut(j * SEG);
                    let cur: &[f32] = if j == 0 { &self.x } else { &head[(j - 1) * SEG..] };
                    sched.step_into(
                        tj,
                        cur,
                        &eps,
                        xi,
                        &mut self.x0_scratch,
                        &mut tail[..SEG],
                        &mut self.means[j * SEG..(j + 1) * SEG],
                    );
                }
            }
        }

        // states[j] = input latent of draft j: x, then samples[0..k-1].
        self.states.clear();
        self.states.extend_from_slice(&self.x);
        self.states.extend_from_slice(&self.samples[..k.saturating_sub(1) * SEG]);

        // Padded verify inputs (pad with the last real state).
        self.verify_xs.clear();
        self.verify_ts.clear();
        for j in 0..VERIFY_BATCH {
            let jj = j.min(k - 1);
            self.verify_xs.extend_from_slice(&self.states[jj * SEG..(jj + 1) * SEG]);
            self.verify_ts.push((t - jj) as f32);
        }

        self.nfe += k as f64 * DRAFTER_NFE;
        self.stage = Stage::Verify;
        Ok(())
    }

    /// Stage 2+3 — accept scan over the verified drafts.
    ///
    /// `eps_t` is this job's slice of the (possibly fused) verify output,
    /// VERIFY_BATCH × SEG. Commits the accepted prefix, corrects the first
    /// rejection by reflection-maximal coupling, and advances `t`.
    pub fn accept(&mut self, eps_t: &[f32], rng: &mut Rng) {
        debug_assert_eq!(self.stage, Stage::Verify);
        debug_assert!(eps_t.len() >= self.k * SEG);
        let (t, k) = (self.round_t, self.k);
        let sched = self.sched;
        let mut probs = Vec::with_capacity(k);
        let mut accepted = 0usize;
        let mut coupled = None;
        let mut committed = 0usize;
        for j in 0..k {
            let tj = t - j;
            let state = &self.states[j * SEG..(j + 1) * SEG];
            let sample = &self.samples[j * SEG..(j + 1) * SEG];
            let mu_d = &self.means[j * SEG..(j + 1) * SEG];
            // Target posterior mean at the same state — into scratch, no
            // per-draft allocation.
            let eps_j = &eps_t[j * SEG..(j + 1) * SEG];
            sched.predict_x0(tj, state, eps_j, &mut self.x0_scratch);
            sched.posterior_mean(tj, state, &self.x0_scratch, &mut self.mu_scratch);

            let sigma = sched.sigmas[tj];
            let sigma_eff = (sigma * self.params.sigma_scale).max(1e-6);
            let xi = &self.noise[j * SEG..(j + 1) * SEG];
            let mode = if self.stochastic_accept {
                acceptance::AcceptMode::Stochastic
            } else {
                acceptance::AcceptMode::Threshold(self.params.lambda)
            };
            let (ok, p) = acceptance::accept_draft(mu_d, &self.mu_scratch, sigma_eff, xi, mode, rng);
            probs.push(p);
            if ok {
                accepted += 1;
                committed = j + 1;
                self.x.copy_from_slice(sample);
            } else {
                // Reflection-maximal coupling with the *sampling* σ so the
                // corrected sample is exactly N(μ_t, σ²) (lossless).
                let result = coupling::reflection_couple(sample, mu_d, &self.mu_scratch, sigma, rng);
                coupled = Some(result.coupled);
                self.x.copy_from_slice(&result.sample);
                committed = j + 1;
                break;
            }
        }
        self.nfe += 1.0; // one (possibly fused) target forward per request
        self.rounds.push(RoundRecord {
            t_start: t,
            k,
            accepted,
            committed,
            probs,
            coupled,
            params: self.params,
        });
        self.t -= committed;
        self.stage = if self.t == 0 { Stage::Final } else { Stage::Draft };
    }

    /// Final deterministic step at t = 0 (σ_0 = 0).
    pub fn finalize(&mut self, den: &dyn Denoiser) -> Result<()> {
        debug_assert_eq!(self.stage, Stage::Final);
        let eps = den.target_step(&self.x, 0, &self.cond)?;
        self.sched.predict_x0(0, &self.x, &eps, &mut self.x0_scratch);
        self.sched.posterior_mean(0, &self.x, &self.x0_scratch, &mut self.mu_scratch);
        self.output.clear();
        self.output.extend_from_slice(&self.mu_scratch);
        self.nfe += 1.0;
        self.stage = Stage::Done;
        Ok(())
    }

    /// Consume the job: (segment, rounds, nfe). Valid once [`Stage::Done`].
    pub fn into_parts(self) -> (Vec<f32>, Vec<RoundRecord>, f64) {
        debug_assert_eq!(self.stage, Stage::Done);
        (self.output, self.rounds, self.nfe)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::OBS_DIM;
    use crate::policy::mock::MockDenoiser;
    use crate::speculative::{SegmentTrace, SpecEngine};

    /// Driving the state machine stage-by-stage must equal the engine's
    /// one-shot driver exactly (same rng stream → same bits, same NFE).
    #[test]
    fn state_machine_matches_engine_driver() {
        let m = MockDenoiser::with_bias(0.15);
        let cond = Denoiser::encode(&m, &vec![0.3; OBS_DIM]).unwrap();
        let params = SpecParams::fixed_k(8);

        let engine = SpecEngine::new();
        let mut rng_a = Rng::seed_from_u64(77);
        let mut trace = SegmentTrace::default();
        let seg_a = engine
            .generate_segment(&m, &cond, |_| params, &mut rng_a, &mut trace)
            .unwrap();

        let sched = DdpmSchedule::cosine(DIFFUSION_STEPS);
        let mut rng_b = Rng::seed_from_u64(77);
        let mut job = SegmentJob::new(&sched, false, cond.clone(), &mut rng_b);
        loop {
            match job.stage() {
                Stage::Draft => job.draft(&m, params, &mut rng_b).unwrap(),
                Stage::DraftWave => unreachable!("draft() is atomic"),
                Stage::Verify => {
                    let eps = m
                        .target_verify(job.verify_xs(), job.verify_ts(), &cond)
                        .unwrap();
                    job.accept(&eps, &mut rng_b);
                }
                Stage::Final => job.finalize(&m).unwrap(),
                Stage::Done => break,
            }
        }
        let (seg_b, rounds, nfe) = job.into_parts();
        assert_eq!(seg_a, seg_b, "stage-driven and one-shot segments must be bit-identical");
        assert_eq!(trace.nfe, nfe);
        assert_eq!(trace.rounds.len(), rounds.len());
        for (a, b) in trace.rounds.iter().zip(&rounds) {
            assert_eq!(a.t_start, b.t_start);
            assert_eq!(a.committed, b.committed);
            assert_eq!(a.accepted, b.accepted);
        }
    }

    /// Driving the draft stage split (begin_draft → rollout_request →
    /// drafter_rollout_many → finish_draft, as the coordinator's draft-
    /// wave table does) must be bit-identical to the monolithic draft()
    /// — including through the serial fallback, which is what the mock
    /// (no fused rollout) exercises.
    #[test]
    fn wave_split_draft_matches_monolithic_draft() {
        let m = MockDenoiser::with_bias(0.12);
        let cond = Denoiser::encode(&m, &vec![0.45; OBS_DIM]).unwrap();
        let params = SpecParams::fixed_k(8);
        let sched = DdpmSchedule::cosine(DIFFUSION_STEPS);

        let run = |split: bool| {
            let mut rng = Rng::seed_from_u64(123);
            let mut job = SegmentJob::new(&sched, false, cond.clone(), &mut rng);
            loop {
                match job.stage() {
                    Stage::Draft => {
                        if split {
                            job.begin_draft(params, &mut rng);
                            let rollouts = {
                                let reqs = [job.rollout_request()];
                                m.drafter_rollout_many(&reqs).unwrap()
                            };
                            let [rollout] = <[_; 1]>::try_from(rollouts).unwrap();
                            job.finish_draft(&m, rollout).unwrap();
                        } else {
                            job.draft(&m, params, &mut rng).unwrap();
                        }
                    }
                    Stage::DraftWave => unreachable!("finish_draft always follows"),
                    Stage::Verify => {
                        let eps =
                            m.target_verify(job.verify_xs(), job.verify_ts(), &cond).unwrap();
                        job.accept(&eps, &mut rng);
                    }
                    Stage::Final => job.finalize(&m).unwrap(),
                    Stage::Done => break,
                }
            }
            job.into_parts()
        };
        let (seg_mono, rounds_mono, nfe_mono) = run(false);
        let (seg_wave, rounds_wave, nfe_wave) = run(true);
        assert_eq!(seg_wave, seg_mono, "split draft must be bit-identical");
        assert_eq!(nfe_wave, nfe_mono);
        assert_eq!(rounds_wave.len(), rounds_mono.len());
        for (a, b) in rounds_wave.iter().zip(&rounds_mono) {
            assert_eq!(a.t_start, b.t_start);
            assert_eq!(a.k, b.k);
            assert_eq!(a.accepted, b.accepted);
            assert_eq!(a.committed, b.committed);
        }
    }

    /// Interleaving two jobs' stages (as the micro-batching engine does)
    /// must not change either job's output vs running it alone.
    #[test]
    fn interleaved_jobs_match_solo_runs() {
        let m = MockDenoiser::with_bias(0.1);
        let cond_a = Denoiser::encode(&m, &vec![0.2; OBS_DIM]).unwrap();
        let cond_b = Denoiser::encode(&m, &vec![0.6; OBS_DIM]).unwrap();
        let params = SpecParams::fixed_k(6);
        let sched = DdpmSchedule::cosine(DIFFUSION_STEPS);

        let solo = |cond: &[f32], seed: u64| {
            let mut rng = Rng::seed_from_u64(seed);
            let mut job = SegmentJob::new(&sched, false, cond.to_vec(), &mut rng);
            loop {
                match job.stage() {
                    Stage::Draft => job.draft(&m, params, &mut rng).unwrap(),
                    Stage::DraftWave => unreachable!("draft() is atomic"),
                    Stage::Verify => {
                        let eps =
                            m.target_verify(job.verify_xs(), job.verify_ts(), cond).unwrap();
                        job.accept(&eps, &mut rng);
                    }
                    Stage::Final => job.finalize(&m).unwrap(),
                    Stage::Done => break,
                }
            }
            job.into_parts()
        };
        let (seg_a_solo, _, nfe_a) = solo(&cond_a, 5);
        let (seg_b_solo, _, nfe_b) = solo(&cond_b, 9);

        // Interleaved: both jobs advance one stage per "engine iteration",
        // verifies fused through target_verify_many.
        let mut rng_a = Rng::seed_from_u64(5);
        let mut rng_b = Rng::seed_from_u64(9);
        let mut job_a = SegmentJob::new(&sched, false, cond_a.clone(), &mut rng_a);
        let mut job_b = SegmentJob::new(&sched, false, cond_b.clone(), &mut rng_b);
        while job_a.stage() != Stage::Done || job_b.stage() != Stage::Done {
            if job_a.stage() == Stage::Draft {
                job_a.draft(&m, params, &mut rng_a).unwrap();
            }
            if job_b.stage() == Stage::Draft {
                job_b.draft(&m, params, &mut rng_b).unwrap();
            }
            let a_pending = job_a.stage() == Stage::Verify;
            let b_pending = job_b.stage() == Stage::Verify;
            if a_pending || b_pending {
                let mut xs = Vec::new();
                let mut ts = Vec::new();
                let mut conds = Vec::new();
                if a_pending {
                    xs.extend_from_slice(job_a.verify_xs());
                    ts.extend_from_slice(job_a.verify_ts());
                    conds.extend_from_slice(job_a.cond());
                }
                if b_pending {
                    xs.extend_from_slice(job_b.verify_xs());
                    ts.extend_from_slice(job_b.verify_ts());
                    conds.extend_from_slice(job_b.cond());
                }
                let eps = m.target_verify_many(&xs, &ts, &conds).unwrap();
                let mut off = 0;
                if a_pending {
                    job_a.accept(&eps[off..off + VERIFY_BATCH * SEG], &mut rng_a);
                    off += VERIFY_BATCH * SEG;
                }
                if b_pending {
                    job_b.accept(&eps[off..off + VERIFY_BATCH * SEG], &mut rng_b);
                }
            }
            if job_a.stage() == Stage::Final {
                job_a.finalize(&m).unwrap();
            }
            if job_b.stage() == Stage::Final {
                job_b.finalize(&m).unwrap();
            }
        }
        let (seg_a, _, nfe_a2) = job_a.into_parts();
        let (seg_b, _, nfe_b2) = job_b.into_parts();
        assert_eq!(seg_a, seg_a_solo);
        assert_eq!(seg_b, seg_b_solo);
        assert_eq!(nfe_a, nfe_a2);
        assert_eq!(nfe_b, nfe_b2);
    }
}
