//! TS-DP speculative decoding engine (paper §3.2).
//!
//! [`job::SegmentJob`] is the resumable Draft → Verify → Accept state
//! machine; [`engine::SpecEngine`] drives a single job to completion,
//! while the serving coordinator holds many jobs in flight and fuses
//! their verify stages across requests.

pub mod engine;
pub mod job;
pub mod trace;

pub use engine::SpecEngine;
pub use job::{SegmentJob, Stage};
pub use trace::{RoundRecord, SegmentTrace};
