//! TS-DP speculative decoding engine (paper §3.2).

pub mod engine;
pub mod trace;

pub use engine::SpecEngine;
pub use trace::{RoundRecord, SegmentTrace};
