//! `ts-dp` — the L3 coordinator CLI.
//!
//! Subcommands (see `ts-dp help`):
//! * `gen-demos`       — generate PH/MH demonstration datasets (build path)
//! * `serve`           — run the serving coordinator over env sessions
//!                       (`--http ADDR` exposes it as an HTTP frontend)
//! * `client`          — closed-loop load generator for `serve --http`
//! * `episode`         — run a single policy episode and print metrics
//! * `train-scheduler` — PPO-train the temporal scheduler
//! * `distill-drafter` — distill a Transformer drafter from the base model
//! * `quantize-drafter` — convert a drafter checkpoint to int8 per-channel
//! * `table`           — regenerate a paper table (1..5, s1..s3)
//! * `figure`          — regenerate a paper figure (3..6) as CSV

use anyhow::{bail, Result};
use ts_dp::util::cli::Args;

fn main() {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        print_help();
        std::process::exit(2);
    }
    let cmd = argv.remove(0);
    let args = Args::parse(argv);
    let result = match cmd.as_str() {
        "gen-demos" => cmd_gen_demos(&args),
        "episode" => ts_dp::harness::cli::cmd_episode(&args),
        "train-scheduler" => ts_dp::scheduler::cli::cmd_train(&args),
        "distill-drafter" => ts_dp::drafter::cli::cmd_distill(&args),
        "quantize-drafter" => ts_dp::drafter::cli::cmd_quantize(&args),
        "table" => ts_dp::harness::cli::cmd_table(&args),
        "figure" => ts_dp::harness::cli::cmd_figure(&args),
        "serve" => ts_dp::coordinator::cli::cmd_serve(&args),
        "client" => ts_dp::coordinator::cli::cmd_client(&args),
        "load-sweep" => ts_dp::coordinator::cli::cmd_load_sweep(&args),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    eprintln!(
        "ts-dp — Temporal-aware Reinforcement Speculative Decoding for Diffusion Policy

USAGE: ts-dp <command> [options]

COMMANDS:
  gen-demos        --out DIR [--episodes N] [--seed S]
  serve            --task T --style ph|mh [--method M] [--sessions N] [--episodes N]
                   | --mix \"lift:ts_dp*4@rt:40ms,push_t:vanilla@batch\"
                   [--shards N] [--policy fair|fifo|priority] [--max-batch N]
                   [--batch-window-us U] [--queue N] [--adaptive]
                   [--adapt frozen|online] [--learner-min-batch N]
                   [--learner-buffer N] [--checkpoint-every N]
                   [--adapted-policy-out FILE]
                   [--drafter FILE [--drafter-dtype f32|int8]]
                   [--qos [--degrade-pressure S] [--aging-limit N]]
                   [--trace-out FILE] [--obs-interval MS [--obs-out FILE]]
                   [--http ADDR [--http-sessions N]]
  client           [--addr HOST:PORT] [--mix SPEC]
  load-sweep       --task T [--method M] | --mix SPEC
                   [--rates 1,5,20] [--requests N]
                   [--drafter FILE [--drafter-dtype f32|int8]]
                   [--scheduler-policy FILE]
                   [--saturate [--multiples 0.5,1,2,4]]
  episode          --task T --style ph|mh [--method M] [--seed S] [--adaptive]
                   [--drafter FILE [--drafter-dtype f32|int8]]
  train-scheduler  --out FILE [--iters N] [--tasks a,b,c]
  distill-drafter  --out FILE [--tasks a,b,c] [--style ph|mh]
                   [--trajectories N] [--steps N] [--window K]
                   [--batch N] [--lr F] [--single-frac F]
  quantize-drafter --drafter FILE [--out FILE]
  table            --id 1|2|3|4|5|s1|s2|s3 [--episodes N] [--out FILE]
  figure           --id 3|4|5|6 [--out-dir DIR]

Workload mixes (--mix): comma-separated task[:method[:style[:episodes]]]
entries, '*N' repeats a session, '@class[:deadline]' sets the QoS class
(rt|interactive|batch) and per-segment latency deadline (e.g. @rt:40ms);
mutually exclusive with --task/--style/--method/--sessions/--episodes.
--shards N serves the mix over N engine shards, each owning its own
model replica.

QoS/overload control: `serve --qos` enables deadline-aware admission
(typed load shedding, accounted per class: offered == served + shed)
and pressure-gated degradation toward drafter-heavy operation;
`--policy priority` serves rt > interactive > batch with an aging rule
so batch is delayed, never starved. `load-sweep --saturate` drives the
stream past measured capacity, FIFO vs QoS side by side.

Drafter swapping: `distill-drafter` trains an in-crate Transformer
drafter against the base model and saves a JSON checkpoint;
`--drafter FILE` on serve/load-sweep/episode swaps it under every
replica (target verification is untouched, so results stay lossless).
`quantize-drafter` converts a checkpoint to int8 per-channel weights
(v2 format); `--drafter-dtype int8` serves any checkpoint quantized
(a v1 checkpoint is quantized in-situ at load). TSDP_KERNELS=
scalar|lanes selects the kernels backend (default: lanes).

Observability: `serve --trace-out trace.json` records the segment
lifecycle (queue wait, admission, draft wave, GEMV, verify, commit,
finalize, scheduler, learner) as a Chrome trace-event file — open it
in Perfetto or chrome://tracing — and folds per-stage p50/p95/p99
wall-time attribution into the fleet summary. `--obs-interval MS`
samples live gauges (queue depth per class, pressure, occupancy,
KV-arena blocks, accept EWMA, sheds) into a JSONL flight record plus
a Prometheus-style .prom exposition at shutdown (path: --obs-out,
default flight.jsonl). Recording never changes served bits.

HTTP serving: `serve --http ADDR` exposes the fleet over a hand-rolled
HTTP/1.1 frontend instead of a CLI-declared workload — POST /v1/sessions
opens a session from a --mix-grammar spec (X-TSDP-Class /
X-TSDP-Deadline-Ms headers override QoS), GET /v1/sessions/{{id}}/segments
streams each segment as chunked NDJSON (one chunk per accepted verify
round), DELETE returns the session report; QoS sheds map to 429/503
with Retry-After. `--http-sessions N` exits after N sessions close
(smoke/CI mode). `ts-dp client --addr HOST:PORT --mix SPEC` replays a
whole mix through that API and cross-checks streamed digests against
each close report.

Online adaptation: `serve --adapt online` keeps PPO-training the
scheduler from live traffic (a background learner publishes
epoch-versioned policy snapshots at segment boundaries) and can
checkpoint the adapted policy with --adapted-policy-out;
`serve --adapt frozen` (or bare --adaptive) replays the checkpoint
bit-identically. `load-sweep --scheduler-policy FILE` sweeps with
scheduler-driven SpecParams, so frozen vs adapted checkpoints can be
compared on identical arrival streams.

Common options:
  --artifacts DIR       artifact directory (default: artifacts)
  --backend artifacts|mock
                        base denoiser: AOT artifacts (default) or the
                        analytic mock [--mock-bias B] (artifact-free)
  --seed S              base RNG seed (default: 0)"
    );
}

/// Build-path command: generate every (task, style) demo dataset.
fn cmd_gen_demos(args: &Args) -> Result<()> {
    let out = args.get_or("out", "artifacts/demos");
    let episodes = args.get_usize("episodes", 40)?;
    let seed = args.get_u64("seed", 0)?;
    let dir = std::path::PathBuf::from(&out);
    if episodes == 0 {
        bail!("--episodes must be positive");
    }
    let summaries = ts_dp::envs::demo::generate_all(&dir, episodes, seed)?;
    println!(
        "{:<12} {:<6} {:>9} {:>9} {:>15}",
        "task", "style", "episodes", "windows", "expert_success"
    );
    for s in &summaries {
        println!(
            "{:<12} {:<6} {:>9} {:>9} {:>14.1}%",
            s.task.name(),
            s.style.name(),
            s.episodes,
            s.windows,
            s.expert_success * 100.0
        );
    }
    println!("wrote {} datasets to {}", summaries.len(), dir.display());
    Ok(())
}
