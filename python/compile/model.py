"""L2: the Diffusion Policy denoiser and its distilled drafter in JAX.

Architecture (sized to train in minutes on CPU while preserving the
paper's 8:1 target:drafter cost ratio):

* **Encoder** — MLP obs[32] -> cond[64]; shared by target and drafter
  ("the draft model shares the same encoder and scheduler with the
  target", paper 3.2).
* **Denoiser** — transformer over the HORIZON action tokens: per-token
  input projection + learned positional embedding + sinusoidal timestep
  embedding + conditioning embedding, then N pre-LN blocks
  (attention -> MLP, both as Pallas kernels), final LN + linear head
  predicting epsilon. Target: 8 blocks. Drafter: 1 block.

All parameters live in plain dicts (pytree), all functions are pure.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np

from compile.config import (
    ACT_DIM,
    DRAFTER_BLOCKS,
    EMBED_DIM,
    HORIZON,
    MLP_HIDDEN,
    NUM_HEADS,
    OBS_DIM,
    TARGET_BLOCKS,
)
from compile.kernels import attention as pallas_kernels
from compile.kernels import ref as ref_kernels

HEAD_DIM = EMBED_DIM // NUM_HEADS

# Kernel backend switch. The Pallas interpret-mode kernels do not define a
# VJP, so training runs on the pure-jnp reference implementations (the
# kernel test suite asserts the two are numerically identical); inference
# and AOT export use the Pallas kernels.
_USE_PALLAS = True


def use_pallas(enabled: bool):
    """Select the kernel backend (True = Pallas L1 kernels)."""
    global _USE_PALLAS
    _USE_PALLAS = enabled


def _attention(q, k, v):
    if _USE_PALLAS:
        return pallas_kernels.attention(q, k, v)
    return ref_kernels.attention_ref(q, k, v)


def _layernorm(x, g, b):
    if _USE_PALLAS:
        return pallas_kernels.layernorm(x, g, b)
    return ref_kernels.layernorm_ref(x, g, b)


def _transformer_mlp(x, w1, b1, w2, b2):
    if _USE_PALLAS:
        return pallas_kernels.transformer_mlp(x, w1, b1, w2, b2)
    return ref_kernels.transformer_mlp_ref(x, w1, b1, w2, b2)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _linear_init(key, fan_in, fan_out):
    scale = 1.0 / math.sqrt(fan_in)
    return {
        "w": jax.random.uniform(key, (fan_in, fan_out), jnp.float32, -scale, scale),
        "b": jnp.zeros((fan_out,), jnp.float32),
    }


def _block_init(key):
    ks = jax.random.split(key, 6)
    return {
        "ln1_g": jnp.ones((EMBED_DIM,)),
        "ln1_b": jnp.zeros((EMBED_DIM,)),
        "qkv": _linear_init(ks[0], EMBED_DIM, 3 * EMBED_DIM),
        "proj": _linear_init(ks[1], EMBED_DIM, EMBED_DIM),
        "ln2_g": jnp.ones((EMBED_DIM,)),
        "ln2_b": jnp.zeros((EMBED_DIM,)),
        "mlp1": _linear_init(ks[2], EMBED_DIM, MLP_HIDDEN),
        "mlp2": _linear_init(ks[3], MLP_HIDDEN, EMBED_DIM),
    }


def init_encoder(key):
    """Observation encoder parameters."""
    k1, k2 = jax.random.split(key)
    return {
        "l1": _linear_init(k1, OBS_DIM, EMBED_DIM),
        "l2": _linear_init(k2, EMBED_DIM, EMBED_DIM),
    }


def init_denoiser(key, num_blocks):
    """Denoiser parameters with the given transformer depth."""
    ks = jax.random.split(key, num_blocks + 5)
    return {
        "in_proj": _linear_init(ks[0], ACT_DIM, EMBED_DIM),
        "pos": 0.02 * jax.random.normal(ks[1], (HORIZON, EMBED_DIM)),
        "t_mlp1": _linear_init(ks[2], EMBED_DIM, EMBED_DIM),
        "t_mlp2": _linear_init(ks[3], EMBED_DIM, EMBED_DIM),
        "blocks": [_block_init(ks[4 + i]) for i in range(num_blocks)],
        "ln_f_g": jnp.ones((EMBED_DIM,)),
        "ln_f_b": jnp.zeros((EMBED_DIM,)),
        "head": _linear_init(ks[4 + num_blocks], EMBED_DIM, ACT_DIM),
    }


def init_all(seed: int = 0):
    """(encoder, target, drafter) parameter pytrees."""
    k = jax.random.PRNGKey(seed)
    ke, kt, kd = jax.random.split(k, 3)
    return init_encoder(ke), init_denoiser(kt, TARGET_BLOCKS), init_denoiser(
        kd, DRAFTER_BLOCKS
    )


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _linear(p, x):
    return x @ p["w"] + p["b"]


def encode(enc, obs):
    """obs[OBS_DIM] -> cond[EMBED_DIM]."""
    h = jnp.tanh(_linear(enc["l1"], obs))
    return _linear(enc["l2"], h)


def _timestep_embedding(t):
    """Sinusoidal embedding of a (float) diffusion timestep."""
    half = EMBED_DIM // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half) / half)
    ang = t * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])


def _block_forward(p, h):
    """One pre-LN transformer block over h[HORIZON, EMBED_DIM]."""
    x = _layernorm(h, p["ln1_g"], p["ln1_b"])
    qkv = _linear(p["qkv"], x)  # [seq, 3*dim]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    # [seq, dim] -> [heads, seq, head_dim]
    def heads(z):
        return z.reshape(HORIZON, NUM_HEADS, HEAD_DIM).transpose(1, 0, 2)
    o = _attention(heads(q), heads(k), heads(v))  # Pallas L1 kernel
    o = o.transpose(1, 0, 2).reshape(HORIZON, EMBED_DIM)
    h = h + _linear(p["proj"], o)
    x = _layernorm(h, p["ln2_g"], p["ln2_b"])
    h = h + _transformer_mlp(
        x, p["mlp1"]["w"], p["mlp1"]["b"], p["mlp2"]["w"], p["mlp2"]["b"]
    )  # Pallas L1 kernel
    return h


def denoise(params, x, t, cond):
    """Predict epsilon.

    Args:
      params: denoiser pytree (target or drafter).
      x: noisy action segment [HORIZON, ACT_DIM].
      t: diffusion timestep (float scalar; integer-valued).
      cond: observation embedding [EMBED_DIM].
    Returns:
      eps prediction [HORIZON, ACT_DIM].
    """
    temb = _timestep_embedding(t)
    temb = _linear(params["t_mlp2"], jnp.tanh(_linear(params["t_mlp1"], temb)))
    h = _linear(params["in_proj"], x) + params["pos"] + temb + cond
    for blk in params["blocks"]:
        h = _block_forward(blk, h)
    h = _layernorm(h, params["ln_f_g"], params["ln_f_b"])
    return _linear(params["head"], h)


def denoise_batch(params, xs, ts, cond):
    """Batched verification pass: xs[B, H, A], ts[B] -> eps[B, H, A].

    One conditioning vector is shared across the batch — this is the
    paper's parallel verification of all drafted steps in a single
    target forward pass.
    """
    return jax.vmap(lambda x, t: denoise(params, x, t, cond))(xs, ts)


# ---------------------------------------------------------------------------
# Parameter (de)serialization — flat f32 vector, for caching to disk.
# ---------------------------------------------------------------------------

def flatten_params(tree):
    """Pytree -> (flat f32 vector, treedef-with-shapes)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [l.shape for l in leaves]
    flat = np.concatenate([np.asarray(l, np.float32).ravel() for l in leaves])
    return flat, (treedef, shapes)


def unflatten_params(flat, spec):
    """Inverse of flatten_params."""
    treedef, shapes = spec
    leaves = []
    i = 0
    for shp in shapes:
        n = int(np.prod(shp)) if shp else 1
        leaves.append(jnp.asarray(flat[i : i + n].reshape(shp)))
        i += n
    return jax.tree.unflatten(treedef, leaves)
