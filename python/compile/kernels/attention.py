"""Pallas L1 kernels: fused attention, transformer MLP and LayerNorm.

These are the compute hot-spots of the denoiser block. The paper's models
run on A100s (cuDNN attention over threadblocks/shared memory); per the
hardware-adaptation note in DESIGN.md we re-express them for the TPU
execution model instead of porting CUDA mechanics:

* **VMEM tiling via BlockSpec** — one grid step per attention head; the
  whole (seq × head_dim) tile for that head lives in VMEM (at our sizes,
  8×16 f32 = 512 B/operand, far under the ~16 MiB VMEM budget), replacing
  the GPU's shared-memory staging.
* **MXU-shaped matmuls** — scores and the weighted sum are expressed as
  single `jnp.dot`s per head so Mosaic can map them onto the 128×128
  systolic array; the softmax stays in VPU registers between them.
* **interpret=True always** — the CPU PJRT plugin cannot execute Mosaic
  custom-calls; interpret mode lowers to plain HLO, which is what the AOT
  pipeline serializes. Real-TPU performance is *estimated* from the
  BlockSpec footprint in DESIGN.md §Perf.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attention_kernel(q_ref, k_ref, v_ref, o_ref):
    """One head per grid step: softmax(q kᵀ / √d) v, fully in VMEM."""
    q = q_ref[0]  # block is [1, seq, head_dim]; drop the head dim
    k = k_ref[0]
    v = v_ref[0]
    scale = 1.0 / jnp.sqrt(jnp.float32(q.shape[-1]))
    scores = jnp.dot(q, k.T) * scale  # MXU matmul 1
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    w = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(w, v)  # MXU matmul 2


def attention(q, k, v):
    """Fused multi-head attention.

    Args:
      q, k, v: [num_heads, seq, head_dim]
    Returns:
      [num_heads, seq, head_dim]
    """
    num_heads, seq, head_dim = q.shape
    spec = pl.BlockSpec((1, seq, head_dim), lambda h: (h, 0, 0))
    return pl.pallas_call(
        _attention_kernel,
        grid=(num_heads,),
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((num_heads, seq, head_dim), q.dtype),
        interpret=True,
    )(q, k, v)


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """Fused position-wise MLP with tanh-approx GELU."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...]) + b1_ref[...]
    g = 0.5 * h * (1.0 + jnp.tanh(0.7978845608 * (h + 0.044715 * h * h * h)))
    o_ref[...] = jnp.dot(g, w2_ref[...]) + b2_ref[...]


def transformer_mlp(x, w1, b1, w2, b2):
    """Fused MLP block. x: [seq, dim] -> [seq, dim].

    A single VMEM tile holds x, both weight matrices and the
    intermediates (dim=64, hidden=128 -> ~64 KiB), so no grid is needed;
    both matmuls feed the MXU back-to-back with the GELU in between.
    """
    seq, dim = x.shape
    return pl.pallas_call(
        _mlp_kernel,
        out_shape=jax.ShapeDtypeStruct((seq, dim), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def _layernorm_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mean) * jax.lax.rsqrt(var + 1e-5) * g_ref[...] + b_ref[...]


def layernorm(x, gamma, beta):
    """Fused LayerNorm over the last axis. x: [seq, dim]."""
    return pl.pallas_call(
        _layernorm_kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, gamma, beta)


def vmem_footprint_bytes(num_heads: int, seq: int, head_dim: int) -> int:
    """Estimated VMEM bytes per attention grid step (perf reporting)."""
    tile = seq * head_dim * 4  # f32
    scores = seq * seq * 4
    # q, k, v, out tiles + score/weight intermediates.
    return 4 * tile + 2 * scores
