"""Pure-jnp oracles for the Pallas kernels.

These are the correctness references: `python/tests/test_kernel.py` sweeps
shapes/dtypes with hypothesis and asserts the Pallas implementations match
these to tight tolerances.
"""

import jax.numpy as jnp


def attention_ref(q, k, v):
    """Multi-head scaled-dot-product attention.

    Args:
      q, k, v: [num_heads, seq, head_dim]
    Returns:
      [num_heads, seq, head_dim]
    """
    dh = q.shape[-1]
    scores = jnp.einsum("hsd,htd->hst", q, k) / jnp.sqrt(jnp.float32(dh))
    weights = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    weights = weights / weights.sum(axis=-1, keepdims=True)
    return jnp.einsum("hst,htd->hsd", weights, v)


def transformer_mlp_ref(x, w1, b1, w2, b2):
    """Position-wise MLP with GELU: x @ w1 + b1 -> gelu -> @ w2 + b2.

    Args:
      x: [seq, dim]; w1: [dim, hidden]; b1: [hidden];
      w2: [hidden, dim]; b2: [dim]
    Returns:
      [seq, dim]
    """
    h = x @ w1 + b1
    # tanh-approx GELU (matches the Pallas kernel).
    g = 0.5 * h * (1.0 + jnp.tanh(0.7978845608 * (h + 0.044715 * h**3)))
    return g @ w2 + b2


def layernorm_ref(x, gamma, beta, eps: float = 1e-5):
    """LayerNorm over the last axis. x: [seq, dim]."""
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta
