"""Build-time training: DP on demonstrations, drafter by distillation.

Two stages, mirroring the paper:

1. **Target DP** — standard DDPM ε-prediction on the (pooled) demo corpus:
   L = E ||ε̂(x_t, t, cond) − ε||².
2. **Drafter distillation** (paper Eq. 7–9) with the target frozen:
   L = λ_gt·||ε̂_d − ε||²  (ground-truth anchor)
     + λ₁·||ε̂_d − ε̂_t||²                (L_pred, Eq. 7)
     + λ₂·||(μ̂_d − μ_t)/σ_t||²          (L_norm, Eq. 8 — the
       scheduler-aware normalized loss on DDPM posterior means).

Adam is hand-rolled (no optax needed for two MLP-scale models).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.config import DIFFUSION_STEPS
from compile.ddpm import Schedule

# Distillation weights (Eq. 9); the ground-truth anchor keeps the drafter
# from collapsing onto early target errors.
LAMBDA_GT = 0.5
LAMBDA_PRED = 1.0
LAMBDA_NORM = 0.1


def adam_init(params):
    """Adam state (m, v, step)."""
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": 0}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8):
    """One Adam step; returns (params, state)."""
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree.map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree.map(lambda v_: v_ / (1 - b2**t), v)
    params = jax.tree.map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh
    )
    return params, {"m": m, "v": v, "t": t}


def _batched_denoise(params, enc, obs, xs, ts):
    """Vectorized denoise over a training batch."""
    def one(o, x, t):
        return model.denoise(params, x, t, model.encode(enc, o))

    return jax.vmap(one)(obs, xs, ts)


def train_target(obs, act, seed=0, steps=4000, batch=256, lr=1e-3, log_every=500):
    """Train encoder + target denoiser. Returns (enc, tgt, loss_history)."""
    # Gradients flow through the jnp reference kernels (Pallas interpret
    # mode defines no VJP); the backends are test-verified identical.
    model.use_pallas(False)
    sched = Schedule()
    enc, tgt, _ = model.init_all(seed)
    params = {"enc": enc, "tgt": tgt}
    opt = adam_init(params)

    obs = jnp.asarray(obs)
    act = jnp.asarray(act)
    n = obs.shape[0]

    def loss_fn(p, o_b, a_b, t_b, eps_b):
        ab = jnp.asarray(sched.alpha_bars)[t_b][:, None, None]
        x_t = jnp.sqrt(ab) * a_b + jnp.sqrt(1.0 - ab) * eps_b
        pred = _batched_denoise(p["tgt"], p["enc"], o_b, x_t, t_b.astype(jnp.float32))
        return jnp.mean((pred - eps_b) ** 2)

    @jax.jit
    def step_fn(p, o, key, lr_now):
        k1, k2, k3 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (batch,), 0, n)
        o_b, a_b = obs[idx], act[idx]
        t_b = jax.random.randint(k2, (batch,), 0, DIFFUSION_STEPS)
        eps_b = jax.random.normal(k3, a_b.shape)
        loss, grads = jax.value_and_grad(loss_fn)(p, o_b, a_b, t_b, eps_b)
        new_p, new_o = adam_update(p, grads, o, lr_now)
        return new_p, new_o, loss

    key = jax.random.PRNGKey(seed + 1)
    history = []
    t0 = time.time()
    import math as _math
    for i in range(steps):
        key, sub = jax.random.split(key)
        # Cosine decay to 10% of the base lr.
        lr_now = lr * (0.1 + 0.9 * 0.5 * (1 + _math.cos(_math.pi * i / steps)))
        params, opt, loss = step_fn(params, opt, sub, lr_now)
        if i % log_every == 0 or i == steps - 1:
            history.append(float(loss))
            print(f"[target] step {i:5d} loss {float(loss):.5f} ({time.time()-t0:.0f}s)")
    return params["enc"], params["tgt"], history


def distill_drafter(
    enc, tgt, obs, act, seed=0, steps=4000, batch=256, lr=1e-3, log_every=500
):
    """Distill the 1-block drafter from the frozen target (Eq. 7–9)."""
    model.use_pallas(False)
    sched = Schedule()
    _, _, drafter = model.init_all(seed + 7)
    opt = adam_init(drafter)
    obs = jnp.asarray(obs)
    act = jnp.asarray(act)
    n = obs.shape[0]
    alpha_bars = jnp.asarray(sched.alpha_bars)
    sigmas = jnp.asarray(sched.sigmas)

    def loss_fn(dp, o_b, a_b, t_b, eps_b):
        ab = alpha_bars[t_b][:, None, None]
        x_t = jnp.sqrt(ab) * a_b + jnp.sqrt(1.0 - ab) * eps_b
        t_f = t_b.astype(jnp.float32)
        eps_d = _batched_denoise(dp, enc, o_b, x_t, t_f)
        eps_t = _batched_denoise(tgt, enc, o_b, x_t, t_f)
        eps_t = jax.lax.stop_gradient(eps_t)
        l_gt = jnp.mean((eps_d - eps_b) ** 2)
        l_pred = jnp.mean((eps_d - eps_t) ** 2)  # Eq. 7

        # Eq. 8: normalized posterior-mean discrepancy. sigma_0 = 0, so
        # guard the denominator (those terms are dropped via the mask).
        def post_mean(eps, x, t):
            x0 = sched.predict_x0(x, eps, t)
            return sched.posterior_mean(x, x0, t)

        mu_d = jax.vmap(post_mean)(eps_d, x_t, t_b)
        mu_t = jax.vmap(post_mean)(eps_t, x_t, t_b)
        sig = sigmas[t_b][:, None, None]
        mask = (sig > 1e-6).astype(jnp.float32)
        l_norm = jnp.mean(mask * ((mu_d - mu_t) / jnp.maximum(sig, 1e-6)) ** 2)
        return LAMBDA_GT * l_gt + LAMBDA_PRED * l_pred + LAMBDA_NORM * l_norm

    @jax.jit
    def step_fn(dp, o, key, lr_now):
        k1, k2, k3 = jax.random.split(key, 3)
        idx = jax.random.randint(k1, (batch,), 0, n)
        o_b, a_b = obs[idx], act[idx]
        t_b = jax.random.randint(k2, (batch,), 0, DIFFUSION_STEPS)
        eps_b = jax.random.normal(k3, a_b.shape)
        loss, grads = jax.value_and_grad(loss_fn)(dp, o_b, a_b, t_b, eps_b)
        new_dp, new_o = adam_update(dp, grads, o, lr_now)
        return new_dp, new_o, loss

    key = jax.random.PRNGKey(seed + 2)
    history = []
    t0 = time.time()
    import math as _math
    for i in range(steps):
        key, sub = jax.random.split(key)
        lr_now = lr * (0.1 + 0.9 * 0.5 * (1 + _math.cos(_math.pi * i / steps)))
        drafter, opt, loss = step_fn(drafter, opt, sub, lr_now)
        if i % log_every == 0 or i == steps - 1:
            history.append(float(loss))
            print(f"[drafter] step {i:5d} loss {float(loss):.5f} ({time.time()-t0:.0f}s)")
    return drafter, history


def save_weights(path, enc, tgt, drafter):
    """Cache trained weights as a single .npz."""
    fe, _ = model.flatten_params(enc)
    ft, _ = model.flatten_params(tgt)
    fd, _ = model.flatten_params(drafter)
    np.savez(path, enc=fe, tgt=ft, drafter=fd)


def load_weights(path):
    """Load cached weights back into parameter pytrees."""
    z = np.load(path)
    enc0, tgt0, drf0 = model.init_all(0)
    _, espec = model.flatten_params(enc0)
    _, tspec = model.flatten_params(tgt0)
    _, dspec = model.flatten_params(drf0)
    return (
        model.unflatten_params(z["enc"], espec),
        model.unflatten_params(z["tgt"], tspec),
        model.unflatten_params(z["drafter"], dspec),
    )
