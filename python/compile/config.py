"""Shared shape constants for the compile pipeline.

These mirror `rust/src/config/mod.rs`; the Rust runtime cross-checks them
against `artifacts/manifest.json` when loading, so a drift fails loudly.
"""

# Observation vector length fed to the encoder (task one-hot + style flag
# + arm state + task features, padded).
OBS_DIM = 32
# Per-step action dimensionality (padded).
ACT_DIM = 8
# Action-segment horizon predicted per denoising episode.
HORIZON = 8
# Observation-embedding width produced by the encoder.
EMBED_DIM = 64
# Number of DDPM denoising steps of the base policy.
DIFFUSION_STEPS = 100
# Maximum draft horizon K per speculative round.
K_MAX = 16
# Batch of the verification executable (bootstrap + K_MAX drafts).
VERIFY_BATCH = K_MAX + 1
# Transformer depth of the target denoiser / the drafter.
TARGET_BLOCKS = 8
DRAFTER_BLOCKS = 1
# Attention heads (EMBED_DIM must divide evenly).
NUM_HEADS = 4
# Hidden width of the per-block MLP.
MLP_HIDDEN = 128
# Fused drafter-rollout artifact variants exported by aot.py.
ROLLOUT_KS = (4, 8, 16)
