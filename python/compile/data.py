"""Load the Rust-generated demonstration datasets.

The Rust demo generator (`ts-dp gen-demos`) writes `<stem>.json` metadata
plus `<stem>.bin` row-major little-endian f32 payloads — trivially
readable with numpy.
"""

import json
from pathlib import Path

import numpy as np

from compile.config import ACT_DIM, HORIZON, OBS_DIM

TASKS = (
    "lift",
    "can",
    "square",
    "transport",
    "tool_hang",
    "push_t",
    "block_push",
    "kitchen",
)
STYLES = ("ph", "mh")


def load_tensor(stem: Path) -> np.ndarray:
    """Read one Rust tensor file pair."""
    meta = json.loads(stem.with_suffix(".json").read_text())
    if meta["dtype"] != "f32":
        raise ValueError(f"unsupported dtype {meta['dtype']} at {stem}")
    data = np.fromfile(stem.with_suffix(".bin"), dtype="<f4")
    return data.reshape(meta["shape"])


def load_dataset(demo_dir: Path, task: str, style: str):
    """(obs[N, OBS_DIM], act[N, HORIZON, ACT_DIM]) for one dataset."""
    obs = load_tensor(demo_dir / f"{task}_{style}_obs")
    act = load_tensor(demo_dir / f"{task}_{style}_act")
    assert obs.shape[1] == OBS_DIM, obs.shape
    assert act.shape[1:] == (HORIZON, ACT_DIM), act.shape
    assert obs.shape[0] == act.shape[0]
    return obs, act


def load_all(demo_dir: Path):
    """Pool every (task, style) dataset into one training corpus.

    The paper trains per-task DPs; we train a single multi-task model
    conditioned on the task one-hot + style flag baked into the
    observation vector (DESIGN.md §2) so a single artifact set serves all
    benchmarks.
    """
    demo_dir = Path(demo_dir)
    obs_all, act_all = [], []
    for task in TASKS:
        for style in STYLES:
            obs, act = load_dataset(demo_dir, task, style)
            obs_all.append(obs)
            act_all.append(act)
    return np.concatenate(obs_all), np.concatenate(act_all)
