"""AOT export: lower the trained models to HLO text for the Rust runtime.

Python runs ONCE here (`make artifacts`); the Rust request path only ever
touches the emitted `artifacts/*.hlo.txt`.

Interchange format is HLO **text**, not serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Exported executables (shapes in artifacts/manifest.json):
  encoder.hlo.txt            obs[32]                      -> (cond[64],)
  target_step.hlo.txt        x[8,8], t[], cond[64]        -> (eps[8,8],)
  target_verify.hlo.txt      xs[17,8,8], ts[17], cond[64] -> (eps[17,8,8],)
  drafter_step.hlo.txt       x[8,8], t[], cond[64]        -> (eps[8,8],)
  drafter_rollout{K}.hlo.txt x[8,8], t0[], cond[64], noise[K,8,8]
                                         -> (xs[K,8,8], means[K,8,8])
"""

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import data as data_mod
from compile import model, train
from compile.config import (
    ACT_DIM,
    DIFFUSION_STEPS,
    DRAFTER_BLOCKS,
    EMBED_DIM,
    HORIZON,
    K_MAX,
    OBS_DIM,
    ROLLOUT_KS,
    TARGET_BLOCKS,
    VERIFY_BATCH,
)
from compile.ddpm import GOLDEN_INDICES, Schedule


def to_hlo_text(lowered) -> str:
    """Lowered jax computation -> XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the model weights are baked into the
    # module as constants; the default text dump elides them as `{...}`,
    # which the Rust-side text parser would reject (or worse, mis-read).
    return comp.as_hlo_text(True)


def export(fn, example_args, out_path: Path) -> int:
    """Lower `fn` at the example shapes and write HLO text."""
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    out_path.write_text(text)
    return len(text)


def make_rollout_fn(drafter, sched: Schedule, k_steps: int):
    """Fused drafter rollout: K serial draft steps in one executable.

    Starting from latent `x` at (float) timestep `t0`, runs the drafter +
    DDPM scheduler K times with the supplied noise draws, recording each
    draft sample and its posterior mean (needed by the verification
    stage, paper §3.2 "retain all draft-model outputs and scheduler
    intermediates").  Timesteps below 0 are clamped (the Rust engine
    never asks for them; clamping keeps the executable total).
    """

    def rollout(x, t0, cond, noise):
        def body(carry, inp):
            x_cur, t_cur = carry
            xi = inp
            t_clamped = jnp.maximum(t_cur, 0.0)
            t_idx = t_clamped.astype(jnp.int32)
            eps = model.denoise(drafter, x_cur, t_clamped, cond)
            x0 = sched.predict_x0(x_cur, eps, t_idx)
            mean = sched.posterior_mean(x_cur, x0, t_idx)
            x_next = mean + sched.sigma(t_idx) * xi
            return (x_next, t_cur - 1.0), (x_next, mean)

        (_, _), (xs, means) = jax.lax.scan(body, (x, t0), noise, length=k_steps)
        return xs, means

    return rollout


def export_all(enc, tgt, drafter, out_dir: Path) -> dict:
    """Export every executable; returns the manifest fragment.

    Kernel-backend note (EXPERIMENTS.md §Perf): the single-step modules
    (encoder, target_step, drafter_step) lower through the Pallas L1
    kernels. The *batched* verify and the scanned rollouts lower through
    the test-identical jnp reference kernels instead — vmap/scan over
    interpret-mode pallas_call lowers to a serial loop in HLO, which made
    the batched verification slower than 17 serial steps (16.2ms vs
    11.4ms on this host). The jnp path vmaps into single batched GEMMs.
    """
    sched = Schedule()
    x_spec = jax.ShapeDtypeStruct((HORIZON, ACT_DIM), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((), jnp.float32)
    cond_spec = jax.ShapeDtypeStruct((EMBED_DIM,), jnp.float32)
    obs_spec = jax.ShapeDtypeStruct((OBS_DIM,), jnp.float32)
    xs_spec = jax.ShapeDtypeStruct((VERIFY_BATCH, HORIZON, ACT_DIM), jnp.float32)
    ts_spec = jax.ShapeDtypeStruct((VERIFY_BATCH,), jnp.float32)

    artifacts = {}

    def record(name, nbytes, inputs, outputs):
        artifacts[name] = {
            "file": f"{name}.hlo.txt",
            "bytes": nbytes,
            "inputs": inputs,
            "outputs": outputs,
        }

    t0 = time.time()
    n = export(lambda o: (model.encode(enc, o),), [obs_spec], out_dir / "encoder.hlo.txt")
    record("encoder", n, [["obs", [OBS_DIM]]], [["cond", [EMBED_DIM]]])

    n = export(
        lambda x, t, c: (model.denoise(tgt, x, t, c),),
        [x_spec, t_spec, cond_spec],
        out_dir / "target_step.hlo.txt",
    )
    record(
        "target_step",
        n,
        [["x", [HORIZON, ACT_DIM]], ["t", []], ["cond", [EMBED_DIM]]],
        [["eps", [HORIZON, ACT_DIM]]],
    )

    model.use_pallas(False)  # batched export: jnp backend (see docstring)
    n = export(
        lambda xs, ts, c: (model.denoise_batch(tgt, xs, ts, c),),
        [xs_spec, ts_spec, cond_spec],
        out_dir / "target_verify.hlo.txt",
    )
    model.use_pallas(True)
    record(
        "target_verify",
        n,
        [
            ["xs", [VERIFY_BATCH, HORIZON, ACT_DIM]],
            ["ts", [VERIFY_BATCH]],
            ["cond", [EMBED_DIM]],
        ],
        [["eps", [VERIFY_BATCH, HORIZON, ACT_DIM]]],
    )

    n = export(
        lambda x, t, c: (model.denoise(drafter, x, t, c),),
        [x_spec, t_spec, cond_spec],
        out_dir / "drafter_step.hlo.txt",
    )
    record(
        "drafter_step",
        n,
        [["x", [HORIZON, ACT_DIM]], ["t", []], ["cond", [EMBED_DIM]]],
        [["eps", [HORIZON, ACT_DIM]]],
    )

    model.use_pallas(False)  # scanned rollouts: jnp backend (see docstring)
    for k in ROLLOUT_KS:
        noise_spec = jax.ShapeDtypeStruct((k, HORIZON, ACT_DIM), jnp.float32)
        fn = make_rollout_fn(drafter, sched, k)
        n = export(
            fn,
            [x_spec, t_spec, cond_spec, noise_spec],
            out_dir / f"drafter_rollout{k}.hlo.txt",
        )
        record(
            f"drafter_rollout{k}",
            n,
            [
                ["x", [HORIZON, ACT_DIM]],
                ["t0", []],
                ["cond", [EMBED_DIM]],
                ["noise", [k, HORIZON, ACT_DIM]],
            ],
            [["xs", [k, HORIZON, ACT_DIM]], ["means", [k, HORIZON, ACT_DIM]]],
        )
    model.use_pallas(True)

    print(f"exported {len(artifacts)} HLO modules in {time.time()-t0:.1f}s")
    return artifacts


def write_golden_io(enc, tgt, drafter, out_dir: Path):
    """Golden input/output vectors for the Rust runtime parity test.

    Deterministic inputs -> expected outputs of each executable, so
    `rust/tests/runtime_integration.rs` can assert that the compiled HLO
    reproduces the JAX numerics through the PJRT C API.
    """
    obs = jnp.sin(jnp.arange(OBS_DIM, dtype=jnp.float32) * 0.37)
    cond = model.encode(enc, obs)
    x = jnp.cos(jnp.arange(HORIZON * ACT_DIM, dtype=jnp.float32) * 0.13).reshape(
        HORIZON, ACT_DIM
    )
    t = 42.0
    eps_t = model.denoise(tgt, x, t, cond)
    eps_d = model.denoise(drafter, x, t, cond)
    golden = {
        "obs": [float(v) for v in obs],
        "cond": [float(v) for v in cond],
        "x": [float(v) for v in jnp.ravel(x)],
        "t": t,
        "eps_target": [float(v) for v in jnp.ravel(eps_t)],
        "eps_drafter": [float(v) for v in jnp.ravel(eps_d)],
    }
    (out_dir / "golden_io.json").write_text(json.dumps(golden))


def write_ddpm_golden(out_dir: Path):
    """Schedule golden values for the Rust parity test."""
    s = Schedule()
    golden = {
        "indices": list(GOLDEN_INDICES),
        "betas": [float(s.betas[i]) for i in GOLDEN_INDICES],
        "alpha_bars": [float(s.alpha_bars[i]) for i in GOLDEN_INDICES],
        "sigmas": [float(s.sigmas[i]) for i in GOLDEN_INDICES],
    }
    (out_dir / "ddpm_golden.json").write_text(json.dumps(golden, indent=2))


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="artifact directory")
    p.add_argument("--demos", default=None, help="demo dir (default <out>/demos)")
    p.add_argument("--steps", type=int, default=3000, help="training steps per stage")
    p.add_argument("--batch", type=int, default=256)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--retrain", action="store_true", help="ignore cached weights")
    args = p.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    demo_dir = Path(args.demos) if args.demos else out_dir / "demos"
    weights_path = out_dir / "weights.npz"

    history = {"target": [], "drafter": []}
    if weights_path.exists() and not args.retrain:
        print(f"loading cached weights from {weights_path}")
        enc, tgt, drafter = train.load_weights(weights_path)
    else:
        print(f"training from demos at {demo_dir}")
        obs, act = data_mod.load_all(demo_dir)
        print(f"corpus: {obs.shape[0]} windows")
        enc, tgt, history["target"] = train.train_target(
            obs, act, seed=args.seed, steps=args.steps, batch=args.batch
        )
        drafter, history["drafter"] = train.distill_drafter(
            enc, tgt, obs, act, seed=args.seed, steps=args.steps, batch=args.batch
        )
        train.save_weights(weights_path, enc, tgt, drafter)

    artifacts = export_all(enc, tgt, drafter, out_dir)
    write_ddpm_golden(out_dir)
    write_golden_io(enc, tgt, drafter, out_dir)

    manifest = {
        "obs_dim": OBS_DIM,
        "act_dim": ACT_DIM,
        "horizon": HORIZON,
        "embed_dim": EMBED_DIM,
        "diffusion_steps": DIFFUSION_STEPS,
        "k_max": K_MAX,
        "verify_batch": VERIFY_BATCH,
        "target_blocks": TARGET_BLOCKS,
        "drafter_blocks": DRAFTER_BLOCKS,
        "rollout_ks": list(ROLLOUT_KS),
        "train_loss": history,
        "artifacts": artifacts,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
