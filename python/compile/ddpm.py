"""DDPM cosine schedule and posterior, the JAX twin of
`rust/src/diffusion/schedule.rs`.

Both sides are checked against the same golden values
(`python/tests/test_ddpm.py` and `rust/tests/ddpm_parity.rs`), because the
Rust request path recomputes posterior means/sigmas from the ε outputs of
the AOT executables and any drift would silently corrupt the
Metropolis–Hastings acceptance test.
"""

import jax.numpy as jnp
import numpy as np

from compile.config import DIFFUSION_STEPS

# Clip range for the predicted clean sample (Diffusion Policy's
# clip_sample=True with actions normalized to [-1, 1]).
CLIP = 1.0


def cosine_betas(n: int = DIFFUSION_STEPS) -> np.ndarray:
    """squaredcos_cap_v2 beta schedule (float64 accumulation, f32 out)."""
    def alpha_bar(u):
        return np.cos((u + 0.008) / 1.008 * np.pi / 2) ** 2

    betas = []
    for t in range(n):
        a0 = alpha_bar(t / n)
        a1 = alpha_bar((t + 1) / n)
        betas.append(min(1.0 - a1 / a0, 0.999))
    return np.asarray(betas, dtype=np.float32)


class Schedule:
    """Precomputed schedule quantities (numpy, converted lazily to jnp)."""

    def __init__(self, n: int = DIFFUSION_STEPS):
        self.n = n
        self.betas = cosine_betas(n)
        self.alphas = (1.0 - self.betas).astype(np.float32)
        # f32 cumprod to match the Rust side bit-for-bit-ish.
        alpha_bars = np.empty(n, dtype=np.float32)
        prod = np.float32(1.0)
        for t in range(n):
            prod = np.float32(prod * self.alphas[t])
            alpha_bars[t] = prod
        self.alpha_bars = alpha_bars
        self.alpha_bars_prev = np.concatenate(
            [np.ones(1, dtype=np.float32), alpha_bars[:-1]]
        )
        var = self.betas * (1.0 - self.alpha_bars_prev) / (1.0 - self.alpha_bars)
        var[0] = 0.0
        self.sigmas = np.sqrt(np.maximum(var, 0.0)).astype(np.float32)

    # ---- jnp ops (gather by possibly-traced integer index) ----

    def add_noise(self, x0, eps, t):
        """Forward noising x_t = sqrt(ab_t) x0 + sqrt(1-ab_t) eps."""
        ab = jnp.asarray(self.alpha_bars)[t]
        return jnp.sqrt(ab) * x0 + jnp.sqrt(1.0 - ab) * eps

    def predict_x0(self, x_t, eps, t):
        """Clipped clean-sample prediction from an ε output."""
        ab = jnp.asarray(self.alpha_bars)[t]
        x0 = (x_t - jnp.sqrt(1.0 - ab) * eps) / jnp.sqrt(ab)
        return jnp.clip(x0, -CLIP, CLIP)

    def posterior_mean(self, x_t, x0, t):
        """Mean of q(x_{t-1} | x_t, x0)."""
        ab = jnp.asarray(self.alpha_bars)[t]
        ab_prev = jnp.asarray(self.alpha_bars_prev)[t]
        beta = jnp.asarray(self.betas)[t]
        alpha = jnp.asarray(self.alphas)[t]
        c0 = jnp.sqrt(ab_prev) * beta / (1.0 - ab)
        ct = jnp.sqrt(alpha) * (1.0 - ab_prev) / (1.0 - ab)
        return c0 * x0 + ct * x_t

    def sigma(self, t):
        """Posterior standard deviation σ_t."""
        return jnp.asarray(self.sigmas)[t]

    def step(self, x_t, eps, t, xi):
        """One reverse step; returns (x_{t-1}, posterior mean)."""
        x0 = self.predict_x0(x_t, eps, t)
        mean = self.posterior_mean(x_t, x0, t)
        return mean + self.sigma(t) * xi, mean


# Golden values shared with rust/tests/ddpm_parity.rs (indices 0, 1, 50,
# 98, 99 of the 100-step schedule). Regenerate with:
#   python -c "from compile.ddpm import print_golden; print_golden()"
GOLDEN_INDICES = (0, 1, 50, 98, 99)


def print_golden():
    """Print schedule values for embedding in parity tests."""
    s = Schedule()
    for t in GOLDEN_INDICES:
        print(
            f"t={t}: beta={s.betas[t]:.9f} alpha_bar={s.alpha_bars[t]:.9f} "
            f"sigma={s.sigmas[t]:.9f}"
        )


if __name__ == "__main__":
    print_golden()
