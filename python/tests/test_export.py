"""AOT export pipeline tests: HLO text emission and rollout semantics."""

import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from compile import aot, model
from compile.config import ACT_DIM, EMBED_DIM, HORIZON, OBS_DIM
from compile.ddpm import Schedule


def test_to_hlo_text_emits_parsable_module():
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(lambda x, y: (x @ y + 2.0,)).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text


def test_export_writes_file():
    with tempfile.TemporaryDirectory() as d:
        path = Path(d) / "f.hlo.txt"
        n = aot.export(
            lambda x: (x * 2.0,), [jax.ShapeDtypeStruct((4,), jnp.float32)], path
        )
        assert path.exists()
        assert n == len(path.read_text())
        assert n > 50


def test_rollout_fn_matches_manual_loop():
    # The fused rollout must equal drafter_step + schedule applied K times.
    model.use_pallas(True)
    enc, _, drafter = model.init_all(11)
    sched = Schedule()
    k_steps = 4
    rollout = aot.make_rollout_fn(drafter, sched, k_steps)

    cond = model.encode(enc, jnp.ones(OBS_DIM) * 0.1)
    x0 = jax.random.normal(jax.random.PRNGKey(0), (HORIZON, ACT_DIM))
    noise = jax.random.normal(jax.random.PRNGKey(1), (k_steps, HORIZON, ACT_DIM))
    t0 = 50.0

    xs, means = rollout(x0, t0, cond, noise)
    assert xs.shape == (k_steps, HORIZON, ACT_DIM)
    assert means.shape == (k_steps, HORIZON, ACT_DIM)

    x = x0
    for k in range(k_steps):
        t = int(t0) - k
        eps = model.denoise(drafter, x, float(t), cond)
        x_next, mean = sched.step(x, eps, t, noise[k])
        np.testing.assert_allclose(xs[k], x_next, rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(means[k], mean, rtol=1e-4, atol=1e-5)
        x = x_next


def test_rollout_clamps_below_zero():
    # Asking for more steps than remain must not index out of range.
    model.use_pallas(True)
    enc, _, drafter = model.init_all(12)
    sched = Schedule()
    rollout = aot.make_rollout_fn(drafter, sched, 4)
    cond = model.encode(enc, jnp.zeros(OBS_DIM))
    x0 = jnp.zeros((HORIZON, ACT_DIM))
    noise = jnp.zeros((4, HORIZON, ACT_DIM))
    xs, means = rollout(x0, 1.0, cond, noise)  # steps at t = 1, 0, -1, -2
    assert np.isfinite(np.asarray(xs)).all()
    assert np.isfinite(np.asarray(means)).all()


def test_exported_module_shapes_in_manifest_format():
    # export_all on fresh weights into a temp dir produces every artifact.
    enc, tgt, drafter = model.init_all(13)
    with tempfile.TemporaryDirectory() as d:
        arts = aot.export_all(enc, tgt, drafter, Path(d))
        expected = {
            "encoder",
            "target_step",
            "target_verify",
            "drafter_step",
            "drafter_rollout4",
            "drafter_rollout8",
            "drafter_rollout16",
        }
        assert expected == set(arts)
        for name, meta in arts.items():
            p = Path(d) / meta["file"]
            assert p.exists(), name
            assert p.stat().st_size == meta["bytes"]
        aot.write_ddpm_golden(Path(d))
        assert (Path(d) / "ddpm_golden.json").exists()


def test_encoder_cond_dim():
    enc, _, _ = model.init_all(14)
    cond = model.encode(enc, jnp.zeros(OBS_DIM))
    assert cond.shape == (EMBED_DIM,)
