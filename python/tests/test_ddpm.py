"""DDPM schedule correctness + golden values shared with the Rust side.

The golden numbers below are duplicated in `rust/tests/ddpm_parity.rs`;
if either implementation drifts, one of the two suites fails.
"""

import jax.numpy as jnp
import numpy as np

from compile.config import DIFFUSION_STEPS
from compile.ddpm import GOLDEN_INDICES, Schedule

# index -> (beta, alpha_bar, sigma); regenerate with `python -m compile.ddpm`.
# Duplicated in rust/tests/ddpm_parity.rs.
GOLDEN = {
    0: (0.000631282, 0.999368727, 0.0),
    1: (0.001116937, 0.998252511, 0.020087026),
    50: (0.031546339, 0.478264421, 0.174941048),
    98: (0.749939263, 0.000242857, 0.865674794),
    99: (0.999000013, 0.000000243, 0.999378622),
}


def test_golden_values():
    s = Schedule()
    assert set(GOLDEN) == set(GOLDEN_INDICES)
    for t, (beta, ab, sigma) in GOLDEN.items():
        np.testing.assert_allclose(s.betas[t], beta, rtol=1e-5, atol=1e-9)
        np.testing.assert_allclose(s.alpha_bars[t], ab, rtol=1e-5, atol=1e-9)
        np.testing.assert_allclose(s.sigmas[t], sigma, rtol=1e-5, atol=1e-9)


def test_schedule_shapes_and_monotonicity():
    s = Schedule()
    assert len(s.betas) == DIFFUSION_STEPS
    assert np.all(s.betas > 0) and np.all(s.betas <= 0.999)
    assert np.all(np.diff(s.alpha_bars) < 0)
    assert s.sigmas[0] == 0.0
    assert np.all(s.sigmas[1:] > 0)


def test_add_noise_then_predict_x0_roundtrip():
    s = Schedule()
    x0 = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)) * 0.5).astype(
        jnp.float32
    )
    eps = jnp.asarray(np.random.default_rng(1).normal(size=(8, 8))).astype(jnp.float32)
    for t in [0, 10, 50, 99]:
        x_t = s.add_noise(x0, eps, t)
        rec = s.predict_x0(x_t, eps, t)
        np.testing.assert_allclose(rec, np.clip(x0, -1, 1), rtol=2e-3, atol=2e-3)


def test_reverse_step_at_t0_is_deterministic():
    s = Schedule()
    x = jnp.ones((4,)) * 0.3
    eps = jnp.ones((4,)) * 0.1
    a, mean_a = s.step(x, eps, 0, jnp.ones(4) * 5)
    b, mean_b = s.step(x, eps, 0, jnp.zeros(4))
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(mean_a, mean_b)
