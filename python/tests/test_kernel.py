"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
the core correctness signal for the kernels that the AOT executables are
built from.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.attention import (
    attention,
    layernorm,
    transformer_mlp,
    vmem_footprint_bytes,
)


def rand(key, shape, dtype, scale=1.0):
    return (scale * jax.random.normal(jax.random.PRNGKey(key), shape)).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(
    heads=st.integers(1, 8),
    seq=st.integers(1, 32),
    head_dim=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(heads, seq, head_dim, seed):
    q = rand(seed, (heads, seq, head_dim), jnp.float32)
    k = rand(seed + 1, (heads, seq, head_dim), jnp.float32)
    v = rand(seed + 2, (heads, seq, head_dim), jnp.float32)
    np.testing.assert_allclose(
        attention(q, k, v), ref.attention_ref(q, k, v), rtol=1e-5, atol=1e-5
    )


@settings(max_examples=20, deadline=None)
@given(
    seq=st.integers(1, 32),
    dim=st.sampled_from([8, 16, 64]),
    hidden=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**16),
)
def test_mlp_matches_ref(seq, dim, hidden, seed):
    x = rand(seed, (seq, dim), jnp.float32)
    w1 = rand(seed + 1, (dim, hidden), jnp.float32, 0.2)
    b1 = rand(seed + 2, (hidden,), jnp.float32, 0.1)
    w2 = rand(seed + 3, (hidden, dim), jnp.float32, 0.2)
    b2 = rand(seed + 4, (dim,), jnp.float32, 0.1)
    np.testing.assert_allclose(
        transformer_mlp(x, w1, b1, w2, b2),
        ref.transformer_mlp_ref(x, w1, b1, w2, b2),
        rtol=2e-5,
        atol=2e-5,
    )


@settings(max_examples=20, deadline=None)
@given(
    seq=st.integers(1, 32),
    dim=st.sampled_from([8, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_layernorm_matches_ref(seq, dim, seed):
    x = rand(seed, (seq, dim), jnp.float32, 3.0)
    g = rand(seed + 1, (dim,), jnp.float32)
    b = rand(seed + 2, (dim,), jnp.float32)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm_ref(x, g, b), rtol=2e-5, atol=2e-5
    )


def test_attention_softmax_rows_are_convex_combinations():
    # Output rows must lie inside the convex hull of v rows: with constant
    # v the output equals v exactly.
    q = rand(0, (2, 8, 16), jnp.float32)
    k = rand(1, (2, 8, 16), jnp.float32)
    v = jnp.ones((2, 8, 16))
    np.testing.assert_allclose(attention(q, k, v), v, rtol=1e-6, atol=1e-6)


def test_attention_is_permutation_equivariant_in_keys():
    # Permuting (k, v) jointly must not change the output.
    q = rand(3, (1, 8, 16), jnp.float32)
    k = rand(4, (1, 8, 16), jnp.float32)
    v = rand(5, (1, 8, 16), jnp.float32)
    perm = np.array([3, 1, 4, 0, 7, 5, 2, 6])
    out1 = attention(q, k, v)
    out2 = attention(q, k[:, perm], v[:, perm])
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-5)


def test_attention_numerical_stability_large_logits():
    # Softmax must be max-subtracted: huge q/k magnitudes stay finite.
    q = 100.0 * rand(6, (1, 4, 8), jnp.float32)
    k = 100.0 * rand(7, (1, 4, 8), jnp.float32)
    v = rand(8, (1, 4, 8), jnp.float32)
    out = attention(q, k, v)
    assert np.isfinite(np.asarray(out)).all()


def test_layernorm_output_is_normalized():
    x = rand(9, (8, 64), jnp.float32, 5.0)
    out = layernorm(x, jnp.ones(64), jnp.zeros(64))
    np.testing.assert_allclose(np.asarray(out).mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(out).std(axis=-1), 1.0, atol=1e-3)


def test_vmem_footprint_is_small():
    # The per-head tile must fit comfortably in TPU VMEM (~16 MiB).
    assert vmem_footprint_bytes(4, 8, 16) < 1 << 14
    assert vmem_footprint_bytes(16, 128, 128) < 1 << 21


@pytest.mark.parametrize("dtype", [jnp.float32])
def test_kernels_under_jit(dtype):
    # The kernels must lower inside jit (the AOT path jits everything).
    q = rand(10, (4, 8, 16), dtype)

    @jax.jit
    def f(q):
        return attention(q, q, q)

    np.testing.assert_allclose(f(q), ref.attention_ref(q, q, q), rtol=1e-5, atol=1e-5)
