"""L2 model tests: shapes, interfaces, backend equivalence, training."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, train
from compile.config import (
    ACT_DIM,
    DRAFTER_BLOCKS,
    EMBED_DIM,
    HORIZON,
    OBS_DIM,
    TARGET_BLOCKS,
    VERIFY_BATCH,
)


def setup_function(_):
    # Each test selects its own backend; default to Pallas.
    model.use_pallas(True)


def test_shapes():
    enc, tgt, drf = model.init_all(0)
    cond = model.encode(enc, jnp.zeros(OBS_DIM))
    assert cond.shape == (EMBED_DIM,)
    eps = model.denoise(tgt, jnp.zeros((HORIZON, ACT_DIM)), 50.0, cond)
    assert eps.shape == (HORIZON, ACT_DIM)
    eb = model.denoise_batch(
        tgt, jnp.zeros((VERIFY_BATCH, HORIZON, ACT_DIM)), jnp.zeros(VERIFY_BATCH), cond
    )
    assert eb.shape == (VERIFY_BATCH, HORIZON, ACT_DIM)


def test_target_and_drafter_share_interface():
    # The drafter must be a drop-in replacement (same I/O contract, paper
    # 3.2), differing only in depth.
    enc, tgt, drf = model.init_all(0)
    assert len(tgt["blocks"]) == TARGET_BLOCKS
    assert len(drf["blocks"]) == DRAFTER_BLOCKS
    cond = model.encode(enc, jnp.ones(OBS_DIM))
    x = jnp.ones((HORIZON, ACT_DIM)) * 0.1
    et = model.denoise(tgt, x, 10.0, cond)
    ed = model.denoise(drf, x, 10.0, cond)
    assert et.shape == ed.shape


def test_pallas_and_ref_backends_agree():
    enc, tgt, _ = model.init_all(3)
    cond = model.encode(enc, jnp.arange(OBS_DIM, dtype=jnp.float32) / OBS_DIM)
    x = jax.random.normal(jax.random.PRNGKey(0), (HORIZON, ACT_DIM))
    model.use_pallas(True)
    e1 = model.denoise(tgt, x, 42.0, cond)
    model.use_pallas(False)
    e2 = model.denoise(tgt, x, 42.0, cond)
    np.testing.assert_allclose(e1, e2, rtol=1e-4, atol=1e-5)


def test_batch_matches_single():
    enc, tgt, _ = model.init_all(1)
    cond = model.encode(enc, jnp.ones(OBS_DIM) * 0.2)
    xs = jax.random.normal(jax.random.PRNGKey(1), (VERIFY_BATCH, HORIZON, ACT_DIM))
    ts = jnp.arange(VERIFY_BATCH, dtype=jnp.float32)
    batched = model.denoise_batch(tgt, xs, ts, cond)
    for i in [0, 5, VERIFY_BATCH - 1]:
        single = model.denoise(tgt, xs[i], ts[i], cond)
        np.testing.assert_allclose(batched[i], single, rtol=1e-5, atol=1e-6)


def test_timestep_conditioning_matters():
    enc, tgt, _ = model.init_all(2)
    cond = model.encode(enc, jnp.zeros(OBS_DIM))
    x = jax.random.normal(jax.random.PRNGKey(2), (HORIZON, ACT_DIM))
    e1 = model.denoise(tgt, x, 1.0, cond)
    e2 = model.denoise(tgt, x, 99.0, cond)
    assert float(jnp.abs(e1 - e2).max()) > 1e-4


def test_observation_conditioning_matters():
    enc, tgt, _ = model.init_all(2)
    x = jax.random.normal(jax.random.PRNGKey(3), (HORIZON, ACT_DIM))
    c1 = model.encode(enc, jnp.zeros(OBS_DIM))
    c2 = model.encode(enc, jnp.ones(OBS_DIM))
    e1 = model.denoise(tgt, x, 10.0, c1)
    e2 = model.denoise(tgt, x, 10.0, c2)
    assert float(jnp.abs(e1 - e2).max()) > 1e-4


def test_param_flatten_roundtrip():
    _, tgt, _ = model.init_all(4)
    flat, spec = model.flatten_params(tgt)
    tgt2 = model.unflatten_params(flat, spec)
    assert jax.tree.all(jax.tree.map(lambda a, b: bool(jnp.allclose(a, b)), tgt, tgt2))


def test_training_reduces_loss_quickly():
    # Tiny synthetic corpus: the action is a linear function of obs; a
    # few dozen steps must cut the ε-loss substantially.
    rng = np.random.default_rng(0)
    obs = rng.normal(size=(512, OBS_DIM)).astype(np.float32)
    act = np.tanh(obs[:, :ACT_DIM])[:, None, :].repeat(HORIZON, axis=1)
    _, _, hist = train.train_target(obs, act, steps=61, batch=64, log_every=30)
    assert hist[-1] < hist[0] * 0.7, hist


def test_distillation_pulls_drafter_toward_target():
    rng = np.random.default_rng(1)
    obs = rng.normal(size=(256, OBS_DIM)).astype(np.float32)
    act = np.tanh(obs[:, :ACT_DIM])[:, None, :].repeat(HORIZON, axis=1)
    enc, tgt, _ = train.train_target(obs, act, steps=31, batch=64, log_every=30)
    drafter, hist = train.distill_drafter(
        enc, tgt, obs, act, steps=61, batch=64, log_every=30
    )
    assert hist[-1] < hist[0], hist
    # Distilled drafter must approximate the target better than an
    # untrained drafter on fresh inputs.
    model.use_pallas(False)
    _, _, fresh = model.init_all(99)
    cond = model.encode(enc, jnp.asarray(obs[0]))
    x = jax.random.normal(jax.random.PRNGKey(5), (HORIZON, ACT_DIM))
    et = model.denoise(tgt, x, 50.0, cond)
    e_distilled = model.denoise(drafter, x, 50.0, cond)
    e_fresh = model.denoise(fresh, x, 50.0, cond)
    d_distilled = float(jnp.mean((e_distilled - et) ** 2))
    d_fresh = float(jnp.mean((e_fresh - et) ** 2))
    assert d_distilled < d_fresh, (d_distilled, d_fresh)
