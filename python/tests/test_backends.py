"""Backend-equivalence guarantees for the AOT export split.

aot.py lowers single-step modules through the Pallas kernels and the
batched/scanned modules through the jnp reference kernels (perf — see
EXPERIMENTS.md §Perf). These tests pin the invariant that makes that
split safe: both backends produce identical numerics for the *same*
parameters, on single and batched paths.
"""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.config import ACT_DIM, HORIZON, OBS_DIM, VERIFY_BATCH
from compile.ddpm import Schedule
from compile.aot import make_rollout_fn


def setup_function(_):
    model.use_pallas(True)


def _fixture(seed=21):
    enc, tgt, drf = model.init_all(seed)
    cond = model.encode(enc, jnp.sin(jnp.arange(OBS_DIM, dtype=jnp.float32)))
    x = jax.random.normal(jax.random.PRNGKey(seed), (HORIZON, ACT_DIM))
    return tgt, drf, cond, x


def test_batched_verify_same_numerics_across_backends():
    tgt, _, cond, _ = _fixture()
    xs = jax.random.normal(jax.random.PRNGKey(1), (VERIFY_BATCH, HORIZON, ACT_DIM))
    ts = jnp.arange(VERIFY_BATCH, dtype=jnp.float32) * 3.0
    model.use_pallas(True)
    a = model.denoise_batch(tgt, xs, ts, cond)
    model.use_pallas(False)
    b = model.denoise_batch(tgt, xs, ts, cond)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


def test_rollout_same_numerics_across_backends():
    _, drf, cond, x = _fixture()
    sched = Schedule()
    noise = jax.random.normal(jax.random.PRNGKey(2), (4, HORIZON, ACT_DIM))
    model.use_pallas(True)
    xs_a, mu_a = make_rollout_fn(drf, sched, 4)(x, 50.0, cond, noise)
    model.use_pallas(False)
    xs_b, mu_b = make_rollout_fn(drf, sched, 4)(x, 50.0, cond, noise)
    np.testing.assert_allclose(xs_a, xs_b, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(mu_a, mu_b, rtol=1e-4, atol=1e-5)


def test_mixed_backend_consistency_single_vs_batch():
    # The Rust engine compares target_verify outputs (jnp lowering)
    # against drafter means produced via pallas-lowered modules; the two
    # backends must agree through the full single-vs-batch contract.
    tgt, _, cond, x = _fixture(33)
    model.use_pallas(True)
    single = model.denoise(tgt, x, 42.0, cond)
    model.use_pallas(False)
    xs = jnp.broadcast_to(x, (VERIFY_BATCH, HORIZON, ACT_DIM))
    ts = jnp.full((VERIFY_BATCH,), 42.0)
    batched = model.denoise_batch(tgt, xs, ts, cond)
    for b in range(0, VERIFY_BATCH, 8):
        np.testing.assert_allclose(batched[b], single, rtol=1e-4, atol=1e-5)
