#!/usr/bin/env python3
"""Coarse perf-regression gate over the machine-readable bench output.

Usage:
    check_bench_regression.py --baseline scripts/bench_baseline.json \
        BENCH_speculative.json BENCH_qos.json

Each BENCH_*.json file follows the `ts-dp-bench-v1` schema (see
rust/src/util/benchjson.rs): {"bench": <name>, "records": [{"name", ...,
"p95_s", ...}]}. The baseline maps "<bench>/<record name>" to a
reference p95 in seconds; the gate FAILS when a record's measured p95
exceeds 2x its baseline entry (coarse on purpose — CI runners are
noisy; this catches order-of-magnitude rot, not percent drift).

The baseline may also carry a "p95_ratio_min" list of
{"slow": key, "fast": key, "min": x} entries: both records must be
present, and slow_p95 / fast_p95 must be >= min. Ratios compare two
records from the SAME run, so they are immune to runner speed and gate
relative wins (e.g. batched >= 2x serial drafter rollouts, SIMD lanes
>= 2x forced-scalar kernels) rather than absolute wall-clock.

A "p95_ratio_max" list of {"num": key, "den": key, "max": x} entries is
the overhead-bound mirror of p95_ratio_min: num_p95 / den_p95 must be
<= max. Used to gate that an opt-in feature measured in the same run
stays cheap (e.g. serving with observability on within 2x of off).

An "accept_parity" list of {"a": key, "b": key, "max_diff": d} entries
gates quality instead of speed: |accept_rate(a) - accept_rate(b)| must
be <= max_diff, both records measured in the same run (the int8
quantized drafter must hold accept-rate parity with its f32 source).

Rules:
  * a baselined key missing from the bench output fails (renames and
    dropped measurements must be loud, and must update the baseline);
  * a record named by a ratio or parity entry missing from the output
    fails the same way — a gate that silently stops measuring is rot;
  * a record with no baseline entry only warns (new measurements start
    accumulating before they are gated);
  * baseline values are provisional ceilings until re-measured — see
    scripts/bench_baseline.json.
"""

import argparse
import json
import sys

REGRESSION_FACTOR = 2.0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_files", nargs="+", help="BENCH_*.json files to check")
    ap.add_argument("--baseline", required=True, help="committed baseline JSON")
    args = ap.parse_args()

    with open(args.baseline) as f:
        doc = json.load(f)
    baseline = doc["p95_s"]
    ratios = doc.get("p95_ratio_min", [])
    ratio_maxes = doc.get("p95_ratio_max", [])
    parities = doc.get("accept_parity", [])

    records = {}
    for path in args.bench_files:
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != "ts-dp-bench-v1":
            print(f"ERROR: {path} is not a ts-dp-bench-v1 document", file=sys.stderr)
            return 1
        for rec in doc["records"]:
            records[f"{doc['bench']}/{rec['name']}"] = rec

    failures = []
    for key, ref_p95 in sorted(baseline.items()):
        rec = records.get(key)
        if rec is None:
            failures.append(f"{key}: baselined record missing from bench output")
            continue
        got = rec["p95_s"]
        limit = REGRESSION_FACTOR * ref_p95
        status = "FAIL" if got > limit else "ok"
        print(f"[{status}] {key}: p95={got:.4f}s (baseline {ref_p95:.4f}s, limit {limit:.4f}s)")
        if got > limit:
            failures.append(f"{key}: p95 {got:.4f}s > {limit:.4f}s")

    for gate in ratios:
        slow, fast, floor = gate["slow"], gate["fast"], gate["min"]
        missing = [k for k in (slow, fast) if k not in records]
        if missing:
            for k in missing:
                failures.append(f"ratio gate {slow} / {fast}: record {k} missing")
            continue
        ratio = records[slow]["p95_s"] / max(records[fast]["p95_s"], 1e-12)
        status = "FAIL" if ratio < floor else "ok"
        print(f"[{status}] ratio {slow} / {fast}: {ratio:.2f}x (min {floor:.2f}x)")
        if ratio < floor:
            failures.append(f"ratio {slow} / {fast}: {ratio:.2f}x < {floor:.2f}x")

    for gate in ratio_maxes:
        num, den, ceil = gate["num"], gate["den"], gate["max"]
        missing = [k for k in (num, den) if k not in records]
        if missing:
            for k in missing:
                failures.append(f"ratio-max gate {num} / {den}: record {k} missing")
            continue
        ratio = records[num]["p95_s"] / max(records[den]["p95_s"], 1e-12)
        status = "FAIL" if ratio > ceil else "ok"
        print(f"[{status}] ratio {num} / {den}: {ratio:.2f}x (max {ceil:.2f}x)")
        if ratio > ceil:
            failures.append(f"ratio {num} / {den}: {ratio:.2f}x > {ceil:.2f}x")

    for gate in parities:
        a, b, max_diff = gate["a"], gate["b"], gate["max_diff"]
        missing = [k for k in (a, b) if k not in records]
        if missing:
            for k in missing:
                failures.append(f"parity gate {a} ~ {b}: record {k} missing")
            continue
        diff = abs(records[a]["accept_rate"] - records[b]["accept_rate"])
        status = "FAIL" if diff > max_diff else "ok"
        print(f"[{status}] parity {a} ~ {b}: |diff|={diff:.4f} (max {max_diff:.4f})")
        if diff > max_diff:
            failures.append(f"parity {a} ~ {b}: |diff| {diff:.4f} > {max_diff:.4f}")

    for key in sorted(set(records) - set(baseline)):
        print(f"[warn] {key}: no baseline entry (p95={records[key]['p95_s']:.4f}s)")

    if failures:
        print("\nperf-smoke regression gate FAILED:", file=sys.stderr)
        for f_ in failures:
            print(f"  - {f_}", file=sys.stderr)
        return 1
    print(f"\nperf-smoke gate passed: {len(baseline)} baselined records within "
          f"{REGRESSION_FACTOR}x, {len(ratios) + len(ratio_maxes)} ratio gate(s) and "
          f"{len(parities)} parity gate(s) met.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
