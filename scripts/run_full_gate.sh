#!/usr/bin/env bash
# Turnkey tier-1 + perf gate: everything CI runs, in one local command.
#
#     scripts/run_full_gate.sh [--bless]
#
# Requires a Rust toolchain (rust-toolchain.toml pins 1.84.0) and
# python3; several growth PRs were authored in containers without one,
# so this script is the documented payoff path for ROADMAP Open item 0:
#
#   1. release build;
#   2. full test suite on the default (lanes) kernel path;
#   3. full test suite forced onto the scalar kernel path
#      (TSDP_KERNELS=scalar), excluding the golden trace — the snapshot
#      pins the default path's arithmetic and is path-dependent by
#      design;
#   4. golden serve-trace gate: strict if the committed snapshot exists,
#      explicit bless (then strict re-run) when --bless is passed and it
#      does not — it never self-blesses silently;
#   5. http-smoke: the release binary serving `--http` on a loopback
#      port, driven end-to-end by `ts-dp client` (which cross-checks
#      streamed digests against each session's close report);
#   6. fast-mode benches emitting BENCH_*.json at the repo root;
#   7. scripts/check_bench_regression.py over those files: p95 ceilings,
#      same-run ratio gates (batched >= 2x serial drafter rollouts,
#      lanes >= 2x forced-scalar kernels, elastic autoscale rt-p95 <=
#      frozen), and the int8-vs-f32 accept-parity gate;
#   8. scripts/check_docs.py over the Markdown: every relative link
#      resolves and every #anchor matches a real heading.
#
# After a first successful run on real hardware: commit the blessed
# rust/tests/golden/serve_trace.txt and the BENCH_*.json files, and copy
# the observed p95_s values into scripts/bench_baseline.json (the
# checker applies 2x headroom; the committed numbers are provisional
# ceilings until then).
set -euo pipefail
cd "$(dirname "$0")/.."

BLESS=0
for arg in "$@"; do
    case "$arg" in
        --bless) BLESS=1 ;;
        *) echo "usage: $0 [--bless]" >&2; exit 2 ;;
    esac
done

command -v cargo >/dev/null || {
    echo "error: no cargo in PATH — this gate needs the Rust toolchain" >&2
    exit 1
}
command -v python3 >/dev/null || { echo "error: python3 not found" >&2; exit 1; }

GOLDEN=rust/tests/golden/serve_trace.txt
# Explicit test list for the scalar leg: every integration suite except
# the path-dependent golden trace (mirrors .github/workflows/ci.yml).
SCALAR_TESTS=(--test autoscale --test ddpm_parity --test drafter_distill
    --test http_frontend --test obs_trace --test online_adapt
    --test qos_serving --test runtime_integration --test serve_batching)

echo "==> [1/8] cargo build --release"
(cd rust && cargo build --release)

echo "==> [2/8] cargo test (default lanes kernel path)"
if [ -f "$GOLDEN" ]; then
    (cd rust && TSDP_REQUIRE_GOLDEN=1 cargo test -q)
else
    echo "    (golden snapshot absent — golden_trace deferred to step 4)"
    (cd rust && cargo test -q --lib --bins "${SCALAR_TESTS[@]}")
fi

echo "==> [3/8] cargo test (TSDP_KERNELS=scalar, golden trace excluded)"
(cd rust && TSDP_KERNELS=scalar cargo test -q --lib --bins "${SCALAR_TESTS[@]}")

echo "==> [4/8] golden serve-trace gate"
if [ -f "$GOLDEN" ]; then
    (cd rust && TSDP_REQUIRE_GOLDEN=1 cargo test -q --test golden_trace)
elif [ "$BLESS" = 1 ]; then
    echo "    blessing $GOLDEN (explicit --bless)"
    (cd rust && TSDP_BLESS_GOLDEN=1 cargo test -q --test golden_trace)
    (cd rust && TSDP_REQUIRE_GOLDEN=1 cargo test -q --test golden_trace)
    echo "    NOW COMMIT: git add $GOLDEN"
else
    echo "error: $GOLDEN is not committed; re-run with --bless to" >&2
    echo "generate it explicitly (the gate never self-blesses)" >&2
    exit 1
fi

echo "==> [5/8] http-smoke: release binary serving --http, driven by ts-dp client"
TSDP_BIN=rust/target/release/ts-dp
HTTP_PORT=$((18000 + RANDOM % 2000))
HTTP_LOG=$(mktemp)
"$TSDP_BIN" serve --backend mock --http "127.0.0.1:$HTTP_PORT" --http-sessions 3 \
    --shards 2 >"$HTTP_LOG" 2>&1 &
HTTP_PID=$!
trap 'kill "$HTTP_PID" 2>/dev/null || true' EXIT
# The listener binds before serve prints anything; poll until the
# port answers (replica build time), then drive three sessions.
for _ in $(seq 1 50); do
    if (exec 3<>"/dev/tcp/127.0.0.1/$HTTP_PORT") 2>/dev/null; then break; fi
    sleep 0.2
done
CLIENT_OUT=$("$TSDP_BIN" client --addr "127.0.0.1:$HTTP_PORT" \
    --mix "lift:ts_dp*2,push_t:ts_dp") || {
        echo "error: http-smoke client run failed" >&2
        cat "$HTTP_LOG" >&2
        exit 1
    }
echo "$CLIENT_OUT"
grep -q "sessions=3 " <<<"$CLIENT_OUT" || {
    echo "error: client did not report 3 served sessions" >&2
    cat "$HTTP_LOG" >&2
    exit 1
}
wait "$HTTP_PID" || { echo "error: http server exited nonzero" >&2; cat "$HTTP_LOG" >&2; exit 1; }
trap - EXIT
grep -q -- "--- fleet ---" "$HTTP_LOG" || {
    echo "error: http server printed no fleet report" >&2; cat "$HTTP_LOG" >&2; exit 1
}
rm -f "$HTTP_LOG"
echo "    http-smoke passed (3 sessions streamed over the wire)"

echo "==> [6/8] fast-mode benches (BENCH_*.json at repo root)"
(cd rust && TSDP_BENCH_FAST=1 cargo bench --bench speculative --bench qos)

echo "==> [7/8] perf regression gate"
python3 scripts/check_bench_regression.py \
    --baseline scripts/bench_baseline.json \
    BENCH_speculative.json BENCH_qos.json

echo "==> [8/8] docs link + anchor hygiene"
python3 scripts/check_docs.py

echo "full gate passed."
