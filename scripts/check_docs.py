#!/usr/bin/env python3
"""Markdown hygiene gate: link and anchor checking for the repo docs.

Usage:
    check_docs.py [--root DIR]

Checks `README.md`, `ROADMAP.md`, and `docs/*.md`:

  * every relative link target resolves to a real file or directory
    inside the repository (no dead paths, no escapes above the root);
  * every `#anchor` — same-file or cross-file — matches a heading in
    its target document, using GitHub's slugification (lowercase,
    punctuation stripped, spaces to hyphens, `-N` suffixes for
    duplicate headings);
  * links inside fenced code blocks and inline code spans are ignored
    (they are examples, not navigation);
  * external schemes (`http:`, `https:`, `mailto:`) are skipped — CI
    has no network and availability of other people's servers is not a
    property of this repo.

Exit code 0 when every link holds, 1 with one diagnostic per broken
link otherwise.
"""

import argparse
import re
import sys
from pathlib import Path

# Inline links and images: [text](target) / ![alt](target), with an
# optional "title". Angle-bracketed targets (<...>) are unwrapped.
LINK_RE = re.compile(r"!?\[[^\]]*\]\(\s*<?([^)<>\s]+)>?(?:\s+\"[^\"]*\")?\s*\)")
HEADING_RE = re.compile(r"^(#{1,6})\s+(.+?)\s*$")
FENCE_RE = re.compile(r"^\s*(```|~~~)")
EXTERNAL = ("http://", "https://", "mailto:", "ftp:")


def strip_code(text):
    """Blank out fenced code blocks and inline code spans, preserving
    line numbers so diagnostics stay accurate."""
    out = []
    in_fence = False
    for line in text.splitlines():
        if FENCE_RE.match(line):
            in_fence = not in_fence
            out.append("")
            continue
        if in_fence:
            out.append("")
        else:
            # Inline spans: `...` cannot contain backticks, so a lazy
            # pairwise strip is exact.
            out.append(re.sub(r"`[^`]*`", "``", line))
    return "\n".join(out)


def github_slug(heading, seen):
    """GitHub's anchor algorithm: drop markdown emphasis/code markers,
    lowercase, strip everything but word chars / spaces / hyphens,
    spaces to hyphens, then -1/-2/... for duplicates."""
    text = re.sub(r"[`*_]", "", heading)
    # Inline links in headings anchor on their text, not their target.
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)
    slug = re.sub(r"[^\w\- ]", "", text.lower()).replace(" ", "-")
    if slug in seen:
        seen[slug] += 1
        return f"{slug}-{seen[slug]}"
    seen[slug] = 0
    return slug


def heading_slugs(path, cache):
    if path in cache:
        return cache[path]
    slugs, seen = set(), {}
    body = strip_code(path.read_text(encoding="utf-8"))
    for line in body.splitlines():
        m = HEADING_RE.match(line)
        if m:
            slugs.add(github_slug(m.group(2), seen))
    cache[path] = slugs
    return slugs


def check_file(md, root, cache, errors):
    text = md.read_text(encoding="utf-8")
    clean = strip_code(text)
    for lineno, line in enumerate(clean.splitlines(), start=1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(EXTERNAL) or target.startswith("//"):
                continue
            where = f"{md.relative_to(root)}:{lineno}"
            path_part, _, anchor = target.partition("#")
            if path_part:
                dest = (md.parent / path_part).resolve()
                try:
                    dest.relative_to(root)
                except ValueError:
                    errors.append(f"{where}: link escapes the repo: {target}")
                    continue
                if not dest.exists():
                    errors.append(f"{where}: dead link: {target}")
                    continue
            else:
                dest = md
            if anchor:
                if dest.is_dir() or dest.suffix.lower() != ".md":
                    errors.append(
                        f"{where}: anchor on a non-markdown target: {target}"
                    )
                    continue
                if anchor.lower() not in heading_slugs(dest, cache):
                    errors.append(
                        f"{where}: missing anchor "
                        f"#{anchor} in {dest.relative_to(root)}"
                    )


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repository root (default: the parent of scripts/)",
    )
    args = ap.parse_args()
    root = args.root.resolve()

    files = []
    for name in ("README.md", "ROADMAP.md"):
        p = root / name
        if not p.exists():
            print(f"FAIL: required doc missing: {name}", file=sys.stderr)
            return 1
        files.append(p)
    files.extend(sorted((root / "docs").glob("*.md")))

    cache, errors = {}, []
    for md in files:
        check_file(md, root, cache, errors)

    if errors:
        for e in errors:
            print(f"FAIL: {e}", file=sys.stderr)
        print(f"\n{len(errors)} broken link(s)", file=sys.stderr)
        return 1
    print(f"docs ok: {len(files)} files link-checked")
    return 0


if __name__ == "__main__":
    sys.exit(main())
