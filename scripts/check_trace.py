#!/usr/bin/env python3
"""Validate observability artifacts from a `ts-dp serve` run.

Usage:
    check_trace.py trace.json [--flight flight.jsonl] [--prom flight.prom] \
        [--min-spans N]

Mirrors the structural checks of `rust/src/obs/trace.rs::validate` for
CI smoke runs, where the artifacts are produced by the release binary
rather than an in-process test:

  * the trace is well-formed JSON with a `traceEvents` array;
  * every event carries `ph`/`pid`/`tid`/`ts`/`name`;
  * per lane (tid), timestamps are monotone non-decreasing (metadata
    `M` events exempt);
  * `B`/`E` pairs are balanced and properly nested per lane, and `X`
    complete events have non-negative `dur`;
  * the `otherData` header carries build/run provenance (crate version,
    kernel path, drafter, shard count, workload);
  * optionally, the flight JSONL parses line-by-line with monotone
    per-shard timestamps, and the Prometheus exposition contains the
    expected `tsdp_*` metric families.

Exit code 0 when everything holds, 1 with a diagnostic otherwise.
"""

import argparse
import json
import sys

PROVENANCE_KEYS = ("crate_version", "kernel_path", "drafter", "shards", "workload")
FLIGHT_KEYS = (
    "t_us",
    "shard",
    "queue_depth",
    "queue_by_class",
    "inflight",
    "pressure_secs",
    "accept_ewma",
    "policy_epoch",
    "served",
    "sheds",
)
PROM_FAMILIES = ("tsdp_queue_depth", "tsdp_accept_rate_ewma", "tsdp_requests_served_total")


def fail(msg: str) -> int:
    print(f"check_trace: FAIL: {msg}", file=sys.stderr)
    return 1


def check_trace(path: str, min_spans: int) -> int:
    with open(path) as f:
        doc = json.load(f)
    events = doc.get("traceEvents")
    if not isinstance(events, list):
        return fail(f"{path}: traceEvents missing or not an array")

    other = doc.get("otherData")
    if not isinstance(other, dict):
        return fail(f"{path}: otherData provenance header missing")
    missing = [k for k in PROVENANCE_KEYS if k not in other]
    if missing:
        return fail(f"{path}: provenance keys missing: {missing}")

    last_ts = {}
    stacks = {}
    spans = complete = 0
    for i, ev in enumerate(events):
        for key in ("ph", "pid", "tid", "ts", "name"):
            if key not in ev:
                return fail(f"{path}: event {i} missing {key!r}: {ev}")
        ph, tid, ts, name = ev["ph"], ev["tid"], ev["ts"], ev["name"]
        if ph == "M":
            continue
        if ts < last_ts.get(tid, float("-inf")):
            return fail(f"{path}: lane {tid}: ts {ts} goes backwards at {name}")
        last_ts[tid] = ts
        if ph == "B":
            stacks.setdefault(tid, []).append(name)
        elif ph == "E":
            stack = stacks.setdefault(tid, [])
            if not stack:
                return fail(f"{path}: lane {tid}: E {name} without open B")
            top = stack.pop()
            if top != name:
                return fail(f"{path}: lane {tid}: E {name} closes B {top}")
            spans += 1
        elif ph == "X":
            if ev.get("dur", -1) < 0:
                return fail(f"{path}: lane {tid}: X {name} with missing/negative dur")
            complete += 1
        else:
            return fail(f"{path}: lane {tid}: unsupported ph {ph!r}")
    for tid, stack in stacks.items():
        if stack:
            return fail(f"{path}: lane {tid}: {len(stack)} unclosed B event(s)")

    total = spans + complete
    if total < min_spans:
        return fail(f"{path}: only {total} span(s), expected >= {min_spans}")
    print(
        f"check_trace: {path}: ok — {spans} B/E span(s), {complete} X event(s), "
        f"{len(last_ts)} lane(s), provenance {other['crate_version']}"
        f"/{other['kernel_path']} shards={other['shards']}"
    )
    return 0


def check_flight(path: str) -> int:
    last_by_shard = {}
    n = 0
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                sample = json.loads(line)
            except json.JSONDecodeError as e:
                return fail(f"{path}:{lineno}: not valid JSON: {e}")
            missing = [k for k in FLIGHT_KEYS if k not in sample]
            if missing:
                return fail(f"{path}:{lineno}: keys missing: {missing}")
            shard, t_us = sample["shard"], sample["t_us"]
            if t_us < last_by_shard.get(shard, float("-inf")):
                return fail(f"{path}:{lineno}: shard {shard} t_us goes backwards")
            last_by_shard[shard] = t_us
            n += 1
    if n == 0:
        return fail(f"{path}: no flight samples recorded")
    print(f"check_trace: {path}: ok — {n} sample(s) over {len(last_by_shard)} shard(s)")
    return 0


def check_prom(path: str) -> int:
    with open(path) as f:
        text = f.read()
    missing = [fam for fam in PROM_FAMILIES if fam not in text]
    if missing:
        return fail(f"{path}: metric families missing: {missing}")
    samples = [
        ln for ln in text.splitlines() if ln and not ln.startswith("#")
    ]
    if not samples:
        return fail(f"{path}: no metric samples")
    print(f"check_trace: {path}: ok — {len(samples)} metric sample(s)")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace", help="Chrome trace-event JSON file to validate")
    ap.add_argument("--flight", help="flight-recorder JSONL to validate")
    ap.add_argument("--prom", help="Prometheus exposition file to validate")
    ap.add_argument(
        "--min-spans",
        type=int,
        default=1,
        help="minimum total span/complete events expected in the trace",
    )
    args = ap.parse_args()

    rc = check_trace(args.trace, args.min_spans)
    if rc == 0 and args.flight:
        rc = check_flight(args.flight)
    if rc == 0 and args.prom:
        rc = check_prom(args.prom)
    return rc


if __name__ == "__main__":
    sys.exit(main())
