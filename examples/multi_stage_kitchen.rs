//! Multi-stage Kitchen walkthrough (paper Table 3): run TS-DP on the
//! Franka-Kitchen task and report per-appliance completion (Kit_p1..p4)
//! plus how the speculative parameters interact with the task's
//! coarse-travel / fine-operate phase alternation.
//!
//! ```bash
//! make artifacts && cargo run --release --example multi_stage_kitchen
//! ```

use ts_dp::baselines::make_generator;
use ts_dp::config::{DemoStyle, Method, Task};
use ts_dp::envs::make_env;
use ts_dp::harness::episode::run_episode;
use ts_dp::runtime::ModelRuntime;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let runtime = ModelRuntime::load(&artifacts)?;
    let episodes = 4u64;
    let mut stage_hits = [0u32; 4];
    let mut per_phase_acc: Vec<(f64, usize)> = vec![(0.0, 0); 4];

    for seed in 0..episodes {
        let mut env = make_env(Task::Kitchen, DemoStyle::Ph);
        let mut generator = make_generator(Method::TsDp);
        let r = run_episode(&runtime, env.as_mut(), generator.as_mut(), DemoStyle::Ph, seed, None)?;
        // Stage completion from the continuous score (joints / 4).
        let completed = (r.score * 4.0 + 1e-4).floor() as usize;
        for (x, hit) in stage_hits.iter_mut().enumerate() {
            if completed >= x + 1 {
                *hit += 1;
            }
        }
        // Acceptance per phase (appliance being worked on).
        for s in &r.segments {
            if s.drafts > 0 && s.phase < 4 {
                per_phase_acc[s.phase].0 += s.accepted as f64 / s.drafts as f64;
                per_phase_acc[s.phase].1 += 1;
            }
        }
        println!(
            "episode {seed}: completed {}/4 appliances, nfe/seg {:.1}, acceptance {:.1}%",
            completed,
            r.nfe_percent(),
            r.acceptance_rate() * 100.0
        );
    }
    println!("\nKit_p1..p4 (fraction of episodes completing >= x appliances):");
    for (x, hit) in stage_hits.iter().enumerate() {
        println!("  Kit_p{}: {:.0}%", x + 1, *hit as f64 / episodes as f64 * 100.0);
    }
    println!("\nacceptance by appliance phase:");
    let names = ["microwave", "burner", "switch", "kettle"];
    for (i, (sum, n)) in per_phase_acc.iter().enumerate() {
        if *n > 0 {
            println!("  {:<10} {:.1}% (n={})", names[i], sum / *n as f64 * 100.0, n);
        }
    }
    Ok(())
}
