//! End-to-end distilled-drafter walkthrough, artifact-free: distill a
//! Transformer drafter from the analytic mock target, checkpoint it,
//! reload it, and swap it into the sharded serving fleet — printing the
//! accept-rate improvement and verifying shard-count invariance.
//!
//! Run with: `cargo run --release --example distill_drafter`

use std::time::Duration;
use ts_dp::config::{DemoStyle, Method, SpecParams, StageParams, Task};
use ts_dp::coordinator::batcher::Policy;
use ts_dp::coordinator::server::{serve_with, ServeOptions, ServeReport};
use ts_dp::coordinator::workload::{DrafterKind, WorkloadMix};
use ts_dp::drafter::model::DrafterModel;
use ts_dp::drafter::train::{accept_scorecard, distill, DistillConfig};
use ts_dp::drafter::DistilledDrafter;
use ts_dp::policy::mock::MockDenoiser;
use ts_dp::util::testing::TempDir;

fn serve_fleet(model: DrafterModel, shards: usize) -> anyhow::Result<ServeReport> {
    let opts = ServeOptions {
        workload: WorkloadMix::uniform(Task::Lift, DemoStyle::Ph, Method::TsDp, 4, 1)
            .drafter(DrafterKind::Distilled)
            .build(),
        shards,
        queue_capacity: 64,
        policy: Policy::Fair,
        scheduler: None,
        seed: 7,
        max_batch: 8,
        batch_window: Duration::from_micros(200),
        ..ServeOptions::default()
    };
    serve_with(
        move |_shard| {
            DistilledDrafter::new(Box::new(MockDenoiser::with_bias(0.0)), model.clone())
        },
        &opts,
    )
}

fn main() -> anyhow::Result<()> {
    // 1. Distill against the mock target over two env tasks.
    let target = MockDenoiser::with_bias(0.0);
    let cfg = DistillConfig {
        tasks: vec![Task::Lift, Task::PushT],
        trajectories_per_task: 4,
        steps: 300,
        batch: 6,
        ..Default::default()
    };
    println!("distilling a drafter from the mock target ({} steps)...", cfg.steps);
    let (model, report) = distill(&target, &cfg, |s| {
        println!("  step {:<4} x0 mse {:.6}", s.step, s.loss);
    })?;
    println!("final loss {:.6} over {} trajectories", report.final_loss, report.trajectories);

    // 2. Accept-rate scorecard vs an untrained drafter.
    let eval = SpecParams { stages: StageParams::uniform(8), lambda: 0.3, sigma_scale: 1.0 };
    let (before, after) = accept_scorecard(
        Box::new(MockDenoiser::with_bias(0.0)),
        Box::new(MockDenoiser::with_bias(0.0)),
        &model,
        &cfg.tasks,
        cfg.style,
        2,
        eval,
        99,
    )?;
    println!(
        "accept rate: untrained {:.1}% (nfe/seg {:.1}) -> distilled {:.1}% (nfe/seg {:.1})",
        before.accept_rate * 100.0,
        before.mean_nfe,
        after.accept_rate * 100.0,
        after.mean_nfe
    );

    // 3. Checkpoint roundtrip, then serve the fleet at 1 and 2 shards.
    let dir = TempDir::new("distill_drafter_example");
    let path = dir.path().join("drafter.json");
    model.save(&path)?;
    let loaded = DrafterModel::load(&path)?;
    println!("checkpoint: {} params saved+reloaded", loaded.n_params());
    let one = serve_fleet(loaded.clone(), 1)?;
    let two = serve_fleet(loaded, 2)?;
    println!("1 shard : {}", one.metrics.summary());
    println!("2 shards: {}", two.metrics.summary());
    assert_eq!(
        one.session_fingerprints(),
        two.session_fingerprints(),
        "sharding must not change served actions"
    );
    println!("served segments bit-identical across shard counts — drafter swap is lossless");
    Ok(())
}
