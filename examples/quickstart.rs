//! Quickstart: load the AOT artifacts, run one TS-DP episode on
//! Robomimic-Lift, print the paper's headline metrics.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ts_dp::baselines::make_generator;
use ts_dp::config::{DemoStyle, Method, Task};
use ts_dp::envs::make_env;
use ts_dp::harness::episode::run_episode;
use ts_dp::runtime::ModelRuntime;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    println!("loading artifacts from {} ...", artifacts.display());
    let runtime = ModelRuntime::load(&artifacts)?;

    let mut env = make_env(Task::Lift, DemoStyle::Ph);
    let mut generator = make_generator(Method::TsDp);
    let result =
        run_episode(&runtime, env.as_mut(), generator.as_mut(), DemoStyle::Ph, 0, None)?;

    println!("\n=== TS-DP on Robomimic-Lift (PH) ===");
    println!("success:            {}", result.success);
    println!("env steps:          {}", result.steps);
    println!("segments generated: {}", result.segments.len());
    println!("NFE per segment:    {:.1} (vanilla DP = 100)", result.nfe_percent());
    println!("speedup:            {:.2}x", 100.0 / result.nfe_percent().max(1e-9));
    println!(
        "drafts accepted:    {}/{} ({:.1}%)",
        result.accepted(),
        result.drafts(),
        result.acceptance_rate() * 100.0
    );
    println!("segment latency:    {:.4}s", result.latency_secs());
    println!("control frequency:  {:.2} Hz", result.frequency_hz());
    Ok(())
}
