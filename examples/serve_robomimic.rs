//! End-to-end serving driver (the repository's E2E validation run, see
//! EXPERIMENTS.md): load the real trained model, serve a heterogeneous
//! mixed-task workload from concurrent env sessions across a sharded
//! fleet, and report latency / throughput / success / per-shard verify
//! occupancy — comparing vanilla DP serving against TS-DP serving.
//!
//! Every shard worker compiles and owns its **own** `ModelRuntime`
//! replica (PJRT handles are not `Send`), built by the replica factory
//! passed to `serve`. Sessions are routed once at admission; TS-DP
//! sessions run as resumable jobs whose verify stages fuse across
//! requests within a shard. Served segments are bit-identical to
//! unsharded, unbatched serving.
//!
//! ```bash
//! # first enable the xla dependency in rust/Cargo.toml (see its header)
//! make artifacts && cargo run --release --features pjrt --example serve_robomimic
//! ```
//!
//! (Without `--features pjrt` the binary builds mock-only and the
//! replica factory fails with an actionable message at startup.)

use std::time::Duration;
use ts_dp::config::{DemoStyle, Method, Task};
use ts_dp::coordinator::batcher::Policy;
use ts_dp::coordinator::server::{serve, ServeOptions};
use ts_dp::coordinator::workload::{SessionSpec, WorkloadMix};
use ts_dp::policy::Denoiser;
use ts_dp::runtime::ModelRuntime;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let scheduler = ts_dp::scheduler::SchedulerPolicy::load(
        &artifacts.join("scheduler_policy.json"),
    )
    .ok();
    if scheduler.is_some() {
        println!("(using trained scheduler policy)");
    }

    // Heterogeneous workload: four Robomimic tasks served concurrently
    // in ONE server run, PH and MH styles mixed. The same mix is served
    // once with every session on vanilla DP and once on TS-DP.
    let mix_for = |method: Method| -> Vec<SessionSpec> {
        WorkloadMix::new()
            .sessions(SessionSpec::new(Task::Lift, method), 2)
            .session(SessionSpec::new(Task::Lift, method).with_style(DemoStyle::Mh))
            .sessions(SessionSpec::new(Task::Can, method), 2)
            .sessions(SessionSpec::new(Task::Square, method), 2)
            .session(SessionSpec::new(Task::Transport, method))
            .build()
    };

    const SHARDS: usize = 2;
    for method in [Method::Vanilla, Method::TsDp] {
        println!("\n=== serving mixed Robomimic fleet with {} ===", method.label());
        let opts = ServeOptions {
            workload: mix_for(method),
            shards: SHARDS,
            queue_capacity: 32,
            policy: Policy::Fair,
            scheduler: scheduler.clone(),
            seed: 7,
            max_batch: 8,
            batch_window: Duration::from_micros(200),
            ..ServeOptions::default()
        };
        let t0 = std::time::Instant::now();
        // One runtime replica per shard, compiled on the shard's thread.
        let report = serve(
            &|shard| {
                println!("  shard {shard}: compiling replica from {}", artifacts.display());
                Ok(Box::new(ModelRuntime::load(&artifacts)?) as Box<dyn Denoiser>)
            },
            &opts,
        )?;
        let secs = t0.elapsed().as_secs_f64();
        println!("fleet: {}", report.metrics.summary());
        for m in &report.shard_metrics {
            println!("  {}", m.summary());
        }
        for s in &report.sessions {
            println!(
                "  session {:>2} [shard {}] {:<10} {:<3} segments={:>3} success={} \
                 latency={:.3}s nfe/seg={:.1}",
                s.session,
                s.shard,
                s.task.name(),
                s.style.name(),
                s.segments,
                s.successes,
                s.mean_latency,
                s.nfe / s.segments.max(1) as f64,
            );
        }
        // Serving throughput comes from the fleet metrics clock: each
        // shard's clock arms at its first request, which the readiness
        // barrier guarantees is after every replica finished compiling
        // — so compile time is fully excluded. `wall` includes the
        // compile windows and is reported separately.
        println!(
            "{}: success={:.0}% {:.2} segments/s wall={:.1}s (incl. replica compiles)",
            method.label(),
            report.success_rate() * 100.0,
            report.metrics.throughput(),
            secs
        );
    }
    Ok(())
}
