//! End-to-end serving driver (the repository's E2E validation run, see
//! EXPERIMENTS.md): load the real trained model, serve micro-batched
//! action-segment requests from concurrent env sessions across the
//! Robomimic tasks, and report latency / throughput / success / verify-
//! batch occupancy — comparing vanilla DP serving against TS-DP serving.
//!
//! TS-DP sessions run as resumable jobs whose verify stages fuse across
//! requests (`max_batch` in-flight jobs per engine wave); served
//! segments are bit-identical to unbatched serving.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_robomimic
//! ```

use std::time::Duration;
use ts_dp::config::{DemoStyle, Method, Task};
use ts_dp::coordinator::batcher::Policy;
use ts_dp::coordinator::server::{serve, ServeOptions};
use ts_dp::runtime::ModelRuntime;

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let runtime = ModelRuntime::load(&artifacts)?;
    let scheduler = ts_dp::scheduler::SchedulerPolicy::load(
        &artifacts.join("scheduler_policy.json"),
    )
    .ok();
    if scheduler.is_some() {
        println!("(using trained scheduler policy)");
    }

    let tasks = [Task::Lift, Task::Can, Task::Square, Task::Transport];
    for method in [Method::Vanilla, Method::TsDp] {
        println!("\n=== serving with {} ===", method.label());
        let mut total_segments = 0u64;
        let mut total_secs = 0.0f64;
        for task in tasks {
            let opts = ServeOptions {
                task,
                style: DemoStyle::Ph,
                method,
                sessions: 4,
                episodes_per_session: 1,
                queue_capacity: 32,
                policy: Policy::Fair,
                scheduler: scheduler.clone(),
                seed: 7,
                max_batch: 8,
                batch_window: Duration::from_micros(200),
            };
            let t0 = std::time::Instant::now();
            let report = serve(&runtime, &opts)?;
            let secs = t0.elapsed().as_secs_f64();
            total_segments += report.metrics.requests;
            total_secs += secs;
            println!(
                "{:<10} sessions=4 segments={:>4} success={:>3.0}% \
                 p50={:.3}s p95={:.3}s nfe/seg={:.1} accept={:.1}% \
                 verify-occ={:.2} inflight-peak={} wall={:.1}s",
                task.name(),
                report.metrics.requests,
                report.success_rate() * 100.0,
                report.metrics.latency_percentile(0.5),
                report.metrics.latency_percentile(0.95),
                report.metrics.total_nfe / report.metrics.requests.max(1) as f64,
                report.metrics.acceptance_rate() * 100.0,
                report.metrics.mean_verify_occupancy(),
                report.metrics.peak_inflight,
                secs,
            );
        }
        println!(
            "TOTAL: {:.2} segments/s over {} segments",
            total_segments as f64 / total_secs,
            total_segments
        );
    }
    Ok(())
}
