//! Adaptive vs fixed speculative parameters (paper Table 4 / Fig. 6 in
//! miniature): run the same tasks with fixed-K TS-DP and with the
//! PPO-trained temporal scheduler, and compare success / NFE /
//! acceptance.
//!
//! ```bash
//! make artifacts scheduler && cargo run --release --example adaptive_scheduler
//! ```

use ts_dp::baselines::TsDp;
use ts_dp::config::{DemoStyle, SpecParams, Task};
use ts_dp::envs::make_env;
use ts_dp::harness::episode::run_episode;
use ts_dp::runtime::ModelRuntime;
use ts_dp::scheduler::{SchedulerPolicy, ServingHook};

fn main() -> anyhow::Result<()> {
    let artifacts = std::path::PathBuf::from(
        std::env::args().nth(1).unwrap_or_else(|| "artifacts".into()),
    );
    let runtime = ModelRuntime::load(&artifacts)?;
    let policy = SchedulerPolicy::load(&artifacts.join("scheduler_policy.json"))
        .map_err(|e| anyhow::anyhow!("{e}\nrun `ts-dp train-scheduler` first"))?;

    let tasks = [Task::Lift, Task::Can, Task::Square, Task::Transport];
    let episodes = 3u64;
    println!(
        "{:<11} {:<9} {:>9} {:>9} {:>12} {:>9}",
        "task", "config", "success", "nfe/seg", "acceptance", "drafts"
    );
    for task in tasks {
        for adaptive in [false, true] {
            let mut successes = 0;
            let mut nfe = 0.0;
            let mut acc = 0.0;
            let mut drafts = 0usize;
            let mut segs = 0usize;
            for seed in 0..episodes {
                let mut env = make_env(task, DemoStyle::Ph);
                let mut generator = TsDp::new(SpecParams::fixed_default());
                let r = if adaptive {
                    let mut hook = ServingHook::new(policy.clone());
                    run_episode(
                        &runtime,
                        env.as_mut(),
                        &mut generator,
                        DemoStyle::Ph,
                        seed,
                        Some(&mut hook),
                    )?
                } else {
                    run_episode(&runtime, env.as_mut(), &mut generator, DemoStyle::Ph, seed, None)?
                };
                successes += r.success as u32;
                nfe += r.nfe;
                segs += r.segments.len();
                acc += r.acceptance_rate();
                drafts += r.drafts();
            }
            println!(
                "{:<11} {:<9} {:>7}/{} {:>9.1} {:>11.1}% {:>9}",
                task.name(),
                if adaptive { "adaptive" } else { "fixed" },
                successes,
                episodes,
                nfe / segs.max(1) as f64,
                acc / episodes as f64 * 100.0,
                drafts / episodes as usize,
            );
        }
    }
    Ok(())
}
